//! The mini-batch neighbor-sampled training session (PR 6).
//!
//! [`SampledSession`] is the sampled counterpart of the full-batch
//! [`crate::train::Session`]: each epoch shuffles the train vertices with
//! an epoch-keyed RNG, chunks them into batches, extracts one
//! [`SampledBlock`] per batch (fanout neighbor sampling over the global
//! CSR), gathers the block's remote layer-0 feature rows through the
//! [`ExchangeEngine`] + [`TwoLevelCache`] pair, runs forward/backward on
//! the block with the unchanged `Backend` SpMM kernels, and applies one
//! SGD step per batch (batch-mean gradient), in batch order.
//!
//! # Worker-count-invariant numerics
//!
//! Unlike the full-batch path — where each worker computes a partition
//! and gradients are reduced across workers — a sampled batch is
//! processed *whole* by one worker (`batch % p`). Splitting one batch's
//! block across partitions would make the f32 accumulation order (and so
//! the losses) depend on the partition shape. With whole-batch ownership
//! the worker count only decides *where* compute is charged and how the
//! caches behave (simulated times and bytes), never the numerics; losses
//! are bit-identical across 1/2/4 workers at a fixed seed. Three more
//! invariants make that hold end to end:
//!
//! - model weights draw from a dedicated `seed`-keyed stream (the
//!   partitioners consume a partition-count-dependent amount of the main
//!   stream);
//! - sampling RNG is keyed by `(seed, epoch, batch)` and consumed in
//!   canonical order (see [`crate::sample`]);
//! - when AdaQP quantization is on, **every** block row — local or
//!   remote — is quantized with a vertex-keyed, epoch-free RNG, so a
//!   row's bits never depend on which worker fetched it, on cache state,
//!   or on the epoch. The cache stores exactly these wire rows, which is
//!   why serving a row from cache is bit-identical to fetching it fresh.
//!
//! Simulated time is honest about serialization: one SGD step per batch
//! means batches run back to back, so the epoch time is the *sum* of
//! per-batch compute plus visible communication (with `pipeline` on, a
//! batch's gather overlaps the previous batch's compute) — there is no
//! worker-count speedup, unlike the full-batch barrier model.

use crate::cache::{cal_capacity, key_of, CapacityInput, TwoLevelCache};
use crate::comm::exchange::{ExchangeEngine, ExchangeParams};
use crate::device::simclock::{StageTimes, WallStages};
use crate::dist::Cluster;
use crate::graph::{Dataset, Graph, NodeData};
use crate::model::{layer_stack, GnnModel, LayerDims, ModelKind, TrainedModel};
use crate::partition::halo::{build_plan, SubgraphPlan};
use crate::partition::rapa;
use crate::runtime::Backend;
use crate::sample::{batch_rng, extract_block, BatchSchedule, Fanout, SampledBlock};
use crate::train::report::TrainReport;
use crate::train::session::{charge_compute, quantize_wire, EpochStats, EvalStats, WireRow};
use crate::train::strategy::StrategyKind;
use crate::train::trainer::{CapacityMode, ExecMode, TrainConfig};
use crate::util::Rng;
use anyhow::{anyhow, Result};
use std::collections::HashSet;
use std::sync::mpsc;
use std::time::Instant;

/// Domain tag of the model-init stream (see module docs).
const MODEL_TAG: u64 = 0xD6E8_FEB8_6659_FD93;
/// Domain tag of the per-vertex feature wire stream.
const FEATURE_TAG: u64 = 0x94D0_49BB_1331_11EB;
/// Multiplier mixing a vertex id into a stream key.
const INDEX_MIX: u64 = 0xA24B_AED4_963E_E407;

fn model_rng(seed: u64) -> Rng {
    Rng::new(seed ^ MODEL_TAG)
}

fn feature_rng(seed: u64, v: u32) -> Rng {
    Rng::new(seed ^ FEATURE_TAG ^ (v as u64).wrapping_mul(INDEX_MIX))
}

/// The wire form of vertex `v`'s feature row: raw f32, or stochastically
/// quantized with the vertex-keyed stream when AdaQP is on. A pure
/// function of `(seed, v)` — never of partition, cache state, or epoch.
fn feature_wire(data: &NodeData, v: u32, bits: Option<u8>, seed: u64) -> WireRow {
    let row = data.feature_row(v);
    match bits {
        Some(b) => quantize_wire(row, b, &mut feature_rng(seed, v)),
        None => WireRow { values: row.to_vec(), quantized: true, q8: None },
    }
}

/// Per-epoch accumulators of the batch loop.
struct EpochAcc {
    loss: f32,
    epoch_time: f64,
    comm_time: f64,
    /// Previous batch's owner-side work (pipeline overlap window).
    prev_work: f64,
    bytes_moved: u64,
    bytes_saved: u64,
    sampled_vertices: u64,
    touched: HashSet<u32>,
    peak_block_vertices: usize,
    peak_block_bytes: u64,
    stages: Vec<StageTimes>,
    batches: usize,
}

impl EpochAcc {
    fn new(p: usize) -> EpochAcc {
        EpochAcc {
            loss: 0.0,
            epoch_time: 0.0,
            comm_time: 0.0,
            prev_work: 0.0,
            bytes_moved: 0,
            bytes_saved: 0,
            sampled_vertices: 0,
            touched: HashSet::new(),
            peak_block_vertices: 0,
            peak_block_bytes: 0,
            stages: vec![StageTimes::default(); p],
            batches: 0,
        }
    }
}

/// A materialized sampled-training run: Partition → Cache → Epoch… →
/// finish, mirroring the full-batch [`crate::train::Session`] lifecycle.
pub struct SampledSession<'a> {
    cfg: TrainConfig,
    backend: &'a mut dyn Backend,
    graph: &'a Graph,
    data: &'a NodeData,
    plan: SubgraphPlan,
    /// Owning worker of every global vertex.
    owner_of: Vec<u32>,
    model: GnnModel,
    dims: Vec<LayerDims>,
    c_pad: usize,
    fanout: Fanout,
    train_ids: Vec<u32>,
    val_ids: Vec<u32>,
    test_ids: Vec<u32>,
    cache: TwoLevelCache,
    engine: ExchangeEngine<'a>,
    report: TrainReport,
    epoch: u64,
    total_train: f32,
    wall: Instant,
}

impl<'a> SampledSession<'a> {
    /// Partition the graph over the cluster's devices (the partition
    /// decides halo *ownership* and cache shape — compute ownership is
    /// per batch), size the layer-0 feature cache, and wire the exchange
    /// engine. No epochs run yet.
    pub fn build(
        dataset: &'a Dataset,
        cluster: &'a Cluster,
        backend: &'a mut dyn Backend,
        cfg: &TrainConfig,
    ) -> Result<SampledSession<'a>> {
        let wall = Instant::now();
        let gpus = cluster.gpus();
        let topology = cluster.topology();
        let p = gpus.len();
        assert!(p >= 1);
        let g = &dataset.graph;
        let data = &dataset.data;

        if cfg.batch_size == 0 {
            return Err(anyhow!("sampled mode needs a batch size >= 1"));
        }
        if cfg.strategy == StrategyKind::OneHalfD {
            return Err(anyhow!(
                "the 1.5d strategy supports full-batch training only; use --strategy halo"
            ));
        }
        if cfg.fanout.len() != cfg.layers {
            return Err(anyhow!(
                "sampled mode needs one fanout entry per layer ({} layers), got {}",
                cfg.layers,
                cfg.fanout.len()
            ));
        }
        if cfg.fanout.contains(&0) {
            return Err(anyhow!("fanout entries must be >= 1"));
        }

        // ---- Partition (RAPA or plain) ---------------------------------
        let mut rng = Rng::new(cfg.seed);
        let (plan, rapa_pruned): (SubgraphPlan, usize) = if cfg.use_rapa {
            let mut rcfg = cfg.rapa;
            rcfg.f_dim = data.f_dim;
            rcfg.layers = cfg.layers;
            let res = rapa::run(g, gpus, &rcfg, cfg.method, &mut rng);
            let pruned = res.pruned.iter().sum();
            (res.plan, pruned)
        } else {
            let ps = cfg.method.partition(g, p, &mut rng);
            (build_plan(g, &ps), 0)
        };

        // ---- Model (dedicated stream — see module docs) -----------------
        let c_pad = if data.num_classes <= 4 { 4 } else { 16 };
        if data.num_classes > c_pad {
            return Err(anyhow!("num_classes {} exceeds padded bucket", data.num_classes));
        }
        let dims = layer_stack(data.f_dim, cfg.hidden, c_pad, cfg.layers);
        let model = GnnModel::new(cfg.model, dims.clone(), &mut model_rng(cfg.seed));

        // ---- Ownership + splits ----------------------------------------
        let mut owner_of = vec![0u32; g.n()];
        for (w, sg) in plan.parts.iter().enumerate() {
            for &v in &sg.global_ids[..sg.n_inner] {
                owner_of[v as usize] = w as u32;
            }
        }
        let ids_of = |mask: &[bool]| -> Vec<u32> {
            mask.iter()
                .enumerate()
                .filter(|&(_, &m)| m)
                .map(|(v, _)| v as u32)
                .collect()
        };
        let train_ids = ids_of(&data.train_mask);
        let val_ids = ids_of(&data.val_mask);
        let test_ids = ids_of(&data.test_mask);
        let total_train = (train_ids.len() as f32).max(1.0);

        // ---- Cache: layer-0 feature rows only --------------------------
        // The sampled path never caches intermediate embeddings (blocks
        // change every batch), so capacities scale by one cached layer.
        let max_caps: Vec<usize> = plan.parts.iter().map(|sg| sg.n_halo()).collect();
        let max_global: usize = {
            let mut set = HashSet::new();
            for sg in &plan.parts {
                set.extend(sg.halo_ids().iter().copied());
            }
            set.len()
        };
        let (local_caps, global_cap) = match cfg.capacity {
            CapacityMode::Adaptive => {
                let input = CapacityInput {
                    top_k: usize::MAX,
                    gpu_mem_mib: gpus
                        .iter()
                        .map(|g| g.memory_bytes() as f64 / (1 << 20) as f64)
                        .collect(),
                    gpu_reserved_mib: 100.0,
                    cpu_mem_mib: 768.0 * 1024.0,
                    cpu_reserved_mib: 1024.0,
                    layer_dims: vec![data.f_dim],
                };
                let cap = cal_capacity(&plan, &input);
                (cap.gpu.clone(), cap.cpu)
            }
            CapacityMode::Fixed { local, global } => (vec![local; p], global),
            CapacityMode::Fraction(fr) => (
                max_caps.iter().map(|&c| (c as f64 * fr).ceil() as usize).collect(),
                (max_global as f64 * fr).ceil() as usize,
            ),
        };
        let mut cache =
            TwoLevelCache::with_machines(cfg.policy, &local_caps, global_cap, cluster.machine_of());
        // JACA priorities from the partition plan's halo overlap: sampled
        // batches keep re-requesting exactly those hot 1-hop halo rows.
        // Multi-hop block vertices outside the plan's halo sets default to
        // priority 0 — a deliberately bounded hint memory.
        let max_overlap = plan
            .parts
            .iter()
            .flat_map(|sg| sg.halo_overlap.iter().copied())
            .max()
            .unwrap_or(1);
        for (w, sg) in plan.parts.iter().enumerate() {
            for (hi, &v) in sg.halo_ids().iter().enumerate() {
                let prio = if cfg.invert_priority {
                    max_overlap + 1 - sg.halo_overlap[hi]
                } else {
                    sg.halo_overlap[hi]
                };
                cache.set_priority(w, key_of(0, v), prio);
            }
        }

        let engine = ExchangeEngine::with_machines(gpus, topology, cluster.machine_of());
        let batch_size = cfg.batch_size;
        let report = TrainReport {
            strategy: cfg.strategy.name().to_string(),
            rapa_pruned,
            worker_stages: vec![StageTimes::default(); p],
            batches_per_epoch: train_ids.len().div_ceil(batch_size),
            ..Default::default()
        };

        Ok(SampledSession {
            cfg: cfg.clone(),
            backend,
            graph: g,
            data,
            plan,
            owner_of,
            model,
            dims,
            c_pad,
            fanout: Fanout(cfg.fanout.clone()),
            train_ids,
            val_ids,
            test_ids,
            cache,
            engine,
            report,
            epoch: 0,
            total_train,
            wall,
        })
    }

    /// One-shot convenience: build, run `cfg.epochs` epochs, finish.
    pub fn train(
        dataset: &Dataset,
        cluster: &Cluster,
        backend: &mut dyn Backend,
        cfg: &TrainConfig,
    ) -> Result<TrainReport> {
        let mut session = SampledSession::build(dataset, cluster, backend, cfg)?;
        session.run_epochs(cfg.epochs)?;
        Ok(session.finish()?.0)
    }

    /// Run one sampled epoch: shuffle → extract blocks → per-batch
    /// gather/forward/backward/step in batch order, then a
    /// full-neighborhood validation pass.
    ///
    /// In [`ExecMode::Threaded`], `min(p, batches)` sampler threads
    /// pre-extract blocks for the batches they own (`b ≡ t mod threads`)
    /// through bounded channels while the main thread consumes them in
    /// batch order — a sampling pipeline. Block extraction is a pure
    /// function of the batch's RNG key, so this is bit-identical to
    /// [`ExecMode::Sequential`], including every stat.
    pub fn run_epoch(&mut self) -> Result<EpochStats> {
        let t0 = Instant::now();
        let p = self.plan.parts.len();
        let Self {
            cfg,
            backend,
            graph,
            data,
            owner_of,
            model,
            dims,
            c_pad,
            fanout,
            train_ids,
            val_ids,
            cache,
            engine,
            report,
            total_train,
            epoch: epoch_ref,
            ..
        } = self;
        let backend: &mut dyn Backend = &mut **backend;
        let epoch = *epoch_ref;
        let schedule = BatchSchedule::new(train_ids, cfg.batch_size, cfg.seed, epoch);
        let nb = schedule.n_batches();
        let wall_plan = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let mut acc = EpochAcc::new(p);
        // Epoch-transactional rollback state. Sampled training steps the
        // optimizer per *batch*, so an abort mid-epoch would otherwise
        // leave a partially updated model behind and a retried epoch
        // would silently diverge from a clean run. The model clone is a
        // few weight matrices; the cache image (rolls byte accounting
        // back too) is only taken when a fault plan is armed — the one
        // case the retry loop is expected to replay exactly.
        let model_entry = model.clone();
        let fault = cfg.fault.as_deref();
        let cache_entry = fault.map(|_| cache.snapshot());
        let inject = |b: usize| -> Result<()> {
            let Some(fp) = fault else { return Ok(()) };
            // Worker-scope faults fire on a worker's first batch of the
            // epoch. Batches run on the session thread, so an injected
            // "panic" surfaces as an abort error, not an unwind.
            if b < p {
                let w = (b % p) as u64;
                if fp.worker_panics(epoch, w) {
                    return Err(anyhow!(
                        "injected panic: sampled worker {w} died in epoch {epoch}"
                    ));
                }
                if fp.backend_error(epoch, w) {
                    return Err(anyhow!(
                        "injected transient backend error: sampled worker {w}, epoch {epoch}"
                    ));
                }
            }
            Ok(())
        };
        let run_res: Result<()> = match cfg.exec {
            ExecMode::Sequential => {
                let mut res = Ok(());
                for b in 0..nb {
                    if let Err(e) = inject(b) {
                        res = Err(e);
                        break;
                    }
                    let mut rng = batch_rng(cfg.seed, epoch, b as u64);
                    let block =
                        extract_block(graph, schedule.batch(b), fanout, cfg.model, &mut rng);
                    if let Err(e) = process_batch(
                        &block, b % p, cfg, data, owner_of, model, dims, *c_pad, backend, cache,
                        engine, epoch, *total_train, &mut acc,
                    ) {
                        res = Err(e);
                        break;
                    }
                }
                res
            }
            ExecMode::Threaded => {
                let g: &Graph = graph;
                let threads = p.min(nb).max(1);
                let seed = cfg.seed;
                let kind = cfg.model;
                let fo = fanout.clone();
                let sched = &schedule;
                std::thread::scope(|scope| -> Result<()> {
                    let mut rxs = Vec::with_capacity(threads);
                    for t in 0..threads {
                        let (tx, rx) = mpsc::sync_channel::<SampledBlock>(1);
                        rxs.push(rx);
                        let fo = fo.clone();
                        scope.spawn(move || {
                            for b in (t..nb).step_by(threads) {
                                let mut rng = batch_rng(seed, epoch, b as u64);
                                let block = extract_block(g, sched.batch(b), &fo, kind, &mut rng);
                                if tx.send(block).is_err() {
                                    break;
                                }
                            }
                        });
                    }
                    for b in 0..nb {
                        inject(b)?;
                        let block = rxs[b % threads]
                            .recv()
                            .map_err(|_| anyhow!("sampler thread died"))?;
                        process_batch(
                            &block, b % p, cfg, data, owner_of, model, dims, *c_pad, backend,
                            cache, engine, epoch, *total_train, &mut acc,
                        )?;
                    }
                    Ok(())
                })
            }
        };
        if run_res.is_err() {
            // Abort: sweep content-less pending entries, roll the model
            // back to the epoch boundary, and (under a fault plan) roll
            // the cache image back too — a retried epoch then matches a
            // never-faulted one bit for bit, counters included.
            cache.purge_pending();
            *model = model_entry;
            if let Some(snap) = &cache_entry {
                cache.restore(snap);
            }
        }
        run_res?;
        let wall_execute = t1.elapsed().as_secs_f64();

        // ---- Validation: full-neighborhood inference --------------------
        let t2 = Instant::now();
        let val_acc = split_accuracy(val_ids, cfg, graph, data, model, dims, *c_pad, backend)?;

        // ---- Epoch accounting -------------------------------------------
        let mut mean = StageTimes::default();
        for (w, s) in acc.stages.iter().enumerate() {
            mean.add(s);
            report.worker_stages[w].add(s);
        }
        let mean = mean.scale(1.0 / p as f64);
        report.stage_totals.add(&mean);
        report.epoch_times.push(acc.epoch_time);
        report.comm_times.push(acc.comm_time);
        report.losses.push(acc.loss);
        report.val_accs.push(val_acc);
        report.bytes_moved += acc.bytes_moved;
        report.bytes_saved += acc.bytes_saved;
        report.sampled_vertices += acc.sampled_vertices;
        report.epoch_touched.push(acc.touched.len() as u64);
        report.peak_block_vertices = report.peak_block_vertices.max(acc.peak_block_vertices);
        report.peak_block_bytes = report.peak_block_bytes.max(acc.peak_block_bytes);
        let wall = WallStages {
            plan: wall_plan,
            execute: wall_execute,
            reduce: t2.elapsed().as_secs_f64(),
        };
        report.epoch_wall.push(wall.total());
        report.wall_stages.add(&wall);
        *epoch_ref += 1;

        Ok(EpochStats {
            epoch,
            time: acc.epoch_time,
            comm_time: acc.comm_time,
            loss: acc.loss,
            val_acc,
            bytes_moved: acc.bytes_moved,
            bytes_saved: acc.bytes_saved,
            cross_bytes: 0,
            stages: mean,
            cache: cache.stats,
            batches: acc.batches,
            sampled_vertices: acc.sampled_vertices,
            wall,
        })
    }

    /// Run `n` epochs back to back.
    pub fn run_epochs(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.run_epoch()?;
        }
        Ok(())
    }

    /// Full-neighborhood accuracy on the validation and test splits.
    /// Evaluation bypasses the cache and charges no simulated time; with
    /// full fanout the extraction consumes no RNG, so eval is exactly
    /// reproducible and worker-count-invariant too.
    pub fn eval(&mut self) -> Result<EvalStats> {
        let Self { cfg, backend, graph, data, model, dims, c_pad, val_ids, test_ids, .. } = self;
        let backend: &mut dyn Backend = &mut **backend;
        let val_acc = split_accuracy(val_ids, cfg, graph, data, model, dims, *c_pad, backend)?;
        let test_acc = split_accuracy(test_ids, cfg, graph, data, model, dims, *c_pad, backend)?;
        Ok(EvalStats { val_acc, test_acc })
    }

    /// Close the run: final test accuracy, cache stats, wallclock — plus
    /// the trained weights as a [`TrainedModel`] artifact ready for
    /// `.cgm` export and `capgnn serve`.
    pub fn finish(mut self) -> Result<(TrainReport, TrainedModel)> {
        let ev = self.eval()?;
        self.report.test_acc = ev.test_acc;
        self.report.cache = self.cache.stats;
        self.report.wallclock = self.wall.elapsed().as_secs_f64();
        let SampledSession { cfg, model, report, .. } = self;
        Ok((report, TrainedModel::new(model, cfg.seed)))
    }

    /// Epochs completed so far.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// Forward through all layers on a block; returns the activations
/// (`h[0] = X_block … h[L] = logits`). Shared by sampled training, eval,
/// and the serving path (`crate::serve`): it only reads the block and
/// the model, so identical inputs produce bit-identical activations
/// wherever it runs.
pub(crate) fn forward_block(
    block: &SampledBlock,
    h0: Vec<f32>,
    model: &GnnModel,
    backend: &mut dyn Backend,
) -> Result<Vec<Vec<f32>>> {
    let n = block.n();
    let dims = &model.dims;
    let mut h: Vec<Vec<f32>> = Vec::with_capacity(dims.len() + 1);
    h.push(h0);
    for d in dims {
        h.push(vec![0.0f32; n * d.d_out]);
    }
    for (l, d) in dims.iter().enumerate() {
        let (head, tail) = h.split_at_mut(l + 1);
        let h_in = &head[l];
        let h_out = &mut tail[0];
        match model.kind {
            ModelKind::Gcn => backend.gcn_fwd(
                n,
                d.d_in,
                d.d_out,
                d.relu,
                &block.adj,
                h_in,
                &model.weights[l][0],
                h_out,
            )?,
            ModelKind::Sage => backend.sage_fwd(
                n,
                d.d_in,
                d.d_out,
                d.relu,
                &block.adj,
                h_in,
                &model.weights[l][0],
                &model.weights[l][1],
                h_out,
            )?,
        }
    }
    Ok(h)
}

/// Labels (one-hot, padded) and a seed-row mask for a block.
fn block_targets(
    block: &SampledBlock,
    data: &NodeData,
    c_pad: usize,
) -> (Vec<f32>, Vec<f32>) {
    let n = block.n();
    let mut y = vec![0.0f32; n * c_pad];
    for (i, &v) in block.vertices.iter().enumerate() {
        y[i * c_pad + data.labels[v as usize] as usize] = 1.0;
    }
    let mut mask = vec![0.0f32; n];
    for &r in &block.seed_rows {
        mask[r] = 1.0;
    }
    (y, mask)
}

/// Process one training batch end to end: gather remote layer-0 rows
/// (cache-checked, byte/time-charged), forward, seed-masked loss,
/// backward (the whole block is the computation graph — no halo-gradient
/// zeroing), and one SGD step with the batch-mean gradient.
#[allow(clippy::too_many_arguments)]
fn process_batch(
    block: &SampledBlock,
    owner_w: usize,
    cfg: &TrainConfig,
    data: &NodeData,
    owner_of: &[u32],
    model: &mut GnnModel,
    dims: &[LayerDims],
    c_pad: usize,
    backend: &mut dyn Backend,
    cache: &mut TwoLevelCache,
    engine: &ExchangeEngine<'_>,
    epoch: u64,
    total_train: f32,
    acc: &mut EpochAcc,
) -> Result<()> {
    let n = block.n();
    let f = data.f_dim;
    let layers = dims.len();
    let bits = cfg.quantize_bits;

    // ---- Gather remote layer-0 rows through the cache ------------------
    let requests: Vec<(u32, usize)> = block
        .vertices
        .iter()
        .filter_map(|&v| {
            let o = owner_of[v as usize] as usize;
            (o != owner_w).then_some((v, o))
        })
        .collect();
    let mut params = ExchangeParams::new(0, epoch, f);
    params.use_cache = cfg.use_cache;
    params.comm_multiplier = cfg.comm_multiplier;
    if let Some(bpr) = cfg.quantized_row_bytes {
        params.bytes_per_row = bpr;
    }
    let gather = engine.plan_gather(cache, owner_w, &requests, params);
    // Complete fills immediately: features are static, and the wire row
    // is a pure function of (seed, vertex) — so pending entries never
    // outlive the batch, and cached content is bit-identical to fresh.
    for fl in &gather.fills {
        let wire = feature_wire(data, fl.vertex, bits, cfg.seed);
        cache.complete_fill(fl.key, &wire.values, epoch);
    }

    // ---- Assemble block features ---------------------------------------
    let mut h0 = vec![0.0f32; n * f];
    let mut ri = 0usize;
    let mut full_rows = 0u64; // fetched rows that resisted quantization
    for (i, &v) in block.vertices.iter().enumerate() {
        let remote = owner_of[v as usize] as usize != owner_w;
        let served = if remote {
            let s = gather.rows[ri].as_deref();
            ri += 1;
            s
        } else {
            None
        };
        match served {
            Some(row) => h0[i * f..(i + 1) * f].copy_from_slice(row),
            None => {
                let wire = feature_wire(data, v, bits, cfg.seed);
                if remote && !wire.quantized {
                    full_rows += 1;
                }
                h0[i * f..(i + 1) * f].copy_from_slice(&wire.values);
            }
        }
    }

    // ---- Forward + loss -------------------------------------------------
    let gpu = &engine.gpus[owner_w];
    let mut bstage = StageTimes::default();
    let h = forward_block(block, h0, model, backend)?;
    for d in dims {
        charge_compute(&mut bstage, gpu, block.arcs, n, d.d_in, d.d_out, false, cfg.model);
    }
    let (y, mask) = block_targets(block, data, c_pad);
    let lg = backend.ce_grad(n, c_pad, &h[layers], &y, &mask)?;

    // ---- Backward + step ------------------------------------------------
    let mut grads = model.zero_grads();
    let mut dh = lg.dz;
    let mut dh_prev: Vec<f32> = Vec::new();
    for l in (0..layers).rev() {
        let d = &dims[l];
        match cfg.model {
            ModelKind::Gcn => backend.gcn_bwd(
                n,
                d.d_in,
                d.d_out,
                d.relu,
                &block.adj,
                &h[l],
                &model.weights[l][0],
                &dh,
                &mut grads[l][0],
                &mut dh_prev,
            )?,
            ModelKind::Sage => {
                let (gs, gn) = grads[l].split_at_mut(1);
                backend.sage_bwd(
                    n,
                    d.d_in,
                    d.d_out,
                    d.relu,
                    &block.adj,
                    &h[l],
                    &model.weights[l][0],
                    &model.weights[l][1],
                    &dh,
                    &mut gs[0],
                    &mut gn[0],
                    &mut dh_prev,
                )?;
            }
        }
        std::mem::swap(&mut dh, &mut dh_prev);
        charge_compute(&mut bstage, gpu, block.arcs, n, d.d_in, d.d_out, true, cfg.model);
    }
    model.sgd_step(&grads, cfg.lr);

    // ---- Accounting -----------------------------------------------------
    let weight = block.seed_rows.len() as f32 / total_train;
    acc.loss += lg.loss * weight;
    for (w, s) in gather.stages.iter().enumerate() {
        acc.stages[w].add(s);
    }
    acc.stages[owner_w].add(&bstage);
    let comm_b: f64 = gather.stages.iter().map(|s| s.communication).sum();
    let work_b =
        bstage.total() + gather.stages[owner_w].check_cache + gather.stages[owner_w].pick_cache;
    // With pipelining, a batch's gather overlaps the previous batch's
    // compute (prefetch); only the overhang is visible.
    let visible = if cfg.pipeline { (comm_b - acc.prev_work).max(0.0) } else { comm_b };
    acc.epoch_time += work_b + visible;
    acc.comm_time += visible;
    acc.prev_work = work_b;

    let mut moved = gather.bytes_moved;
    if let Some(bpr) = cfg.quantized_row_bytes {
        let full = (f * 4) as u64;
        if full > bpr {
            // Unquantizable (non-finite) fetched rows crossed at full f32.
            moved += full_rows * (full - bpr);
        }
    }
    acc.bytes_moved += moved;
    acc.bytes_saved += gather.bytes_saved;

    acc.sampled_vertices += n as u64;
    acc.touched.extend(block.vertices.iter().copied());
    acc.peak_block_vertices = acc.peak_block_vertices.max(n);
    let act_bytes: u64 =
        (n * f) as u64 * 4 + dims.iter().map(|d| (n * d.d_out) as u64 * 4).sum::<u64>();
    let adj_bytes = block.arcs as u64 * 8 + (n as u64 + 1) * 4;
    acc.peak_block_bytes = acc.peak_block_bytes.max(act_bytes + adj_bytes);
    acc.batches += 1;
    Ok(())
}

/// Accuracy of the current model on a vertex split, via batched
/// full-neighborhood inference (no sampling, no cache, no time charges).
#[allow(clippy::too_many_arguments)]
fn split_accuracy(
    ids: &[u32],
    cfg: &TrainConfig,
    graph: &Graph,
    data: &NodeData,
    model: &GnnModel,
    dims: &[LayerDims],
    c_pad: usize,
    backend: &mut dyn Backend,
) -> Result<f32> {
    if ids.is_empty() {
        return Ok(0.0);
    }
    let layers = dims.len();
    let full = Fanout::full(layers);
    let bits = cfg.quantize_bits;
    let f = data.f_dim;
    let (mut correct, mut total) = (0.0f32, 0.0f32);
    for chunk in ids.chunks(cfg.batch_size.max(1)) {
        // Full fanout never samples, so the RNG is never consumed.
        let mut rng = Rng::new(0);
        let block = extract_block(graph, chunk, &full, cfg.model, &mut rng);
        let n = block.n();
        let mut h0 = vec![0.0f32; n * f];
        for (i, &v) in block.vertices.iter().enumerate() {
            let wire = feature_wire(data, v, bits, cfg.seed);
            h0[i * f..(i + 1) * f].copy_from_slice(&wire.values);
        }
        let h = forward_block(&block, h0, model, backend)?;
        let (y, mask) = block_targets(&block, data, c_pad);
        let lg = backend.ce_grad(n, c_pad, &h[layers], &y, &mask)?;
        correct += lg.correct;
        total += block.seed_rows.len() as f32;
    }
    Ok(if total > 0.0 { correct / total } else { 0.0 })
}

//! Comparison methods (paper Table 6): Vanilla, DistGCN, CachedGCN
//! (SANCUS), AdaQP — plus the CaPGNN ablation presets of Table 8.
//!
//! Each baseline is a [`TrainConfig`] preset over the same trainer, so the
//! comparison isolates the *policies* (partitioning, caching, staleness,
//! quantization) exactly as the paper's Table 6 taxonomy does. AdaQP's
//! Gurobi bit-width solver is replaced by fixed stochastic int8 + a
//! solver-time model (substitution S5).

use crate::cache::PolicyKind;
use crate::dist::Cluster;
use crate::graph::{Dataset, DatasetSpec};
use crate::model::ModelKind;
use crate::partition::Method;
use crate::runtime::Backend;
use crate::train::{CapacityMode, Session, TrainConfig, TrainReport};
use anyhow::Result;

/// The five compared systems.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    /// SANCUS DistGCN: 2D split, staleness-based broadcast skipping.
    DistGcn,
    /// SANCUS CachedGCN: DistGCN plus a block embedding cache.
    CachedGcn,
    /// Plain partition + full per-layer communication.
    Vanilla,
    /// AdaQP: METIS + pipeline + stochastic int8 quantization.
    AdaQp,
    /// The full system under study (JACA + RAPA + pipeline).
    CaPGnn,
}

/// Every compared system, in the paper's Table 7 column order.
pub const ALL_SYSTEMS: [System; 5] = [
    System::DistGcn,
    System::CachedGcn,
    System::Vanilla,
    System::AdaQp,
    System::CaPGnn,
];

/// Why a run did not produce numbers (paper Table 7 markers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Failure {
    /// The run exceeded the time budget (AdaQP's bit-width ILP).
    Timeout,
    /// The run exceeded device memory.
    Oom,
}

impl System {
    /// Display name (Table 6/7 row label).
    pub fn name(self) -> &'static str {
        match self {
            System::DistGcn => "DistGCN",
            System::CachedGcn => "CachedGCN",
            System::Vanilla => "Vanilla",
            System::AdaQp => "AdaQP",
            System::CaPGnn => "CaPGNN",
        }
    }

    /// Parse a CLI `--system` name (case-insensitive).
    pub fn from_name(s: &str) -> Option<System> {
        match s.to_ascii_lowercase().as_str() {
            "distgcn" => Some(System::DistGcn),
            "cachedgcn" => Some(System::CachedGcn),
            "vanilla" => Some(System::Vanilla),
            "adaqp" => Some(System::AdaQp),
            "capgnn" => Some(System::CaPGnn),
            _ => None,
        }
    }

    /// Does this system support GraphSAGE? (SANCUS variants are GCN-only,
    /// Table 6.)
    pub fn supports_sage(self) -> bool {
        !matches!(self, System::DistGcn | System::CachedGcn)
    }

    /// Build the trainer preset.
    pub fn config(self, epochs: usize, f_dim: usize) -> TrainConfig {
        match self {
            System::CaPGnn => TrainConfig::capgnn(epochs),
            System::Vanilla => TrainConfig::vanilla(epochs),
            System::AdaQp => TrainConfig {
                // METIS + pipeline + adaptive quantization; no cache/RAPA.
                use_rapa: false,
                use_cache: false,
                pipeline: true,
                refresh_interval: 1,
                quantize_bits: Some(8),
                quantized_row_bytes: Some(f_dim as u64 + 8),
                ..TrainConfig::capgnn(epochs)
            },
            System::DistGcn => TrainConfig {
                // SANCUS DistGCN: 2D split (≈ random equal partitions, no
                // halo awareness), staleness-based broadcast skipping,
                // NCCL broadcasts touching every pair.
                method: Method::Random,
                use_rapa: false,
                use_cache: false,
                pipeline: false,
                skip_exchange: true,
                refresh_interval: 4,
                comm_multiplier: 2.5,
                ..TrainConfig::capgnn(epochs)
            },
            System::CachedGcn => TrainConfig {
                // DistGCN + block embedding cache (cuts re-broadcast cost).
                method: Method::Random,
                use_rapa: false,
                use_cache: true,
                policy: PolicyKind::Fifo,
                capacity: CapacityMode::Fraction(1.0),
                pipeline: false,
                skip_exchange: true,
                refresh_interval: 4,
                comm_multiplier: 1.6,
                ..TrainConfig::capgnn(epochs)
            },
        }
    }

    /// Environment-dependent failure model mirroring the paper's observed
    /// Timeout/OOM cells (Table 7): AdaQP's solver times out on
    /// high-feature-dim datasets and many partitions; SANCUS variants and
    /// Vanilla OOM on the largest graphs at high partition counts.
    pub fn failure(self, spec: &DatasetSpec, parts: usize, model: ModelKind) -> Option<Failure> {
        let huge = spec.orig_edges > 50_000_000; // Rt, As, Os class
        let giant = spec.orig_edges > 200_000_000; // As
        let high_dim = original_f_dim(spec) > 5000; // Cl, Cs
        match self {
            System::AdaQp => {
                if high_dim {
                    return Some(Failure::Timeout); // ILP over 8k+ dims
                }
                if giant && parts <= 2 {
                    return Some(Failure::Oom);
                }
                if huge && parts >= 6 {
                    return Some(Failure::Timeout);
                }
                None
            }
            System::DistGcn | System::CachedGcn => {
                if !model_supported(self, model) {
                    return Some(Failure::Oom); // not runnable
                }
                if giant && parts >= 7 {
                    return Some(Failure::Oom); // full replication blows up
                }
                None
            }
            System::Vanilla => {
                if giant && parts >= 8 {
                    return Some(Failure::Oom);
                }
                None
            }
            System::CaPGnn => None,
        }
    }
}

fn model_supported(sys: System, model: ModelKind) -> bool {
    sys.supports_sage() || model == ModelKind::Gcn
}

/// Run one system preset end-to-end on a cluster via the staged
/// [`Session`] — the shared path of the comparison drivers and examples.
pub fn run_preset(
    system: System,
    model: ModelKind,
    epochs: usize,
    dataset: &Dataset,
    cluster: &Cluster,
    backend: &mut dyn Backend,
) -> Result<TrainReport> {
    let mut cfg = system.config(epochs, dataset.data.f_dim);
    cfg.model = model;
    Session::train(dataset, cluster, backend, &cfg)
}

/// The paper-reported feature dims of the original datasets (Table 5),
/// used only by the failure model.
pub fn original_f_dim(spec: &DatasetSpec) -> usize {
    match spec.label {
        "Cl" => 8710,
        "Fr" => 500,
        "Cs" => 8415,
        "Rt" => 602,
        "Yp" => 300,
        "As" => 200,
        "Os" => 100,
        _ => spec.f_dim,
    }
}

/// Ablation arms of Table 8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ablation {
    /// No CaPGNN feature enabled.
    Vanilla,
    /// JACA caching only.
    Jaca,
    /// RAPA partitioning only.
    Rapa,
    /// JACA + RAPA, no pipeline.
    JacaRapa,
    /// JACA + RAPA + pipeline (the full system).
    Full,
}

/// Every ablation arm, in the paper's Table 8 row order.
pub const ABLATIONS: [Ablation; 5] = [
    Ablation::Vanilla,
    Ablation::Jaca,
    Ablation::Rapa,
    Ablation::JacaRapa,
    Ablation::Full,
];

impl Ablation {
    /// Table 8 row label.
    pub fn name(self) -> &'static str {
        match self {
            Ablation::Vanilla => "Vanilla",
            Ablation::Jaca => "+JACA",
            Ablation::Rapa => "+RAPA",
            Ablation::JacaRapa => "+JACA+RAPA",
            Ablation::Full => "+JACA+RAPA+Pipe.",
        }
    }

    /// The trainer preset of this arm.
    pub fn config(self, epochs: usize) -> TrainConfig {
        let base = TrainConfig::capgnn(epochs);
        match self {
            Ablation::Vanilla => TrainConfig::vanilla(epochs),
            Ablation::Jaca => TrainConfig {
                use_rapa: false,
                pipeline: false,
                ..base
            },
            Ablation::Rapa => TrainConfig {
                use_cache: false,
                pipeline: false,
                ..base
            },
            Ablation::JacaRapa => TrainConfig { pipeline: false, ..base },
            Ablation::Full => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::spec_by_name;

    #[test]
    fn names_roundtrip() {
        for s in ALL_SYSTEMS {
            assert_eq!(System::from_name(s.name()), Some(s));
        }
    }

    #[test]
    fn preset_shapes() {
        let cap = System::CaPGnn.config(10, 64);
        assert!(cap.use_cache && cap.use_rapa && cap.pipeline);
        let van = System::Vanilla.config(10, 64);
        assert!(!van.use_cache && !van.use_rapa && !van.pipeline);
        let ada = System::AdaQp.config(10, 64);
        assert_eq!(ada.quantize_bits, Some(8));
        assert!(ada.quantized_row_bytes.unwrap() < 64 * 4);
        let dist = System::DistGcn.config(10, 64);
        assert!(dist.skip_exchange && dist.comm_multiplier > 1.0);
    }

    #[test]
    fn failure_model_matches_paper_patterns() {
        let cl = spec_by_name("Cl").unwrap();
        let as_ = spec_by_name("As").unwrap();
        let rt = spec_by_name("Rt").unwrap();
        // AdaQP times out on high-dim Cl at every partition count.
        assert_eq!(System::AdaQp.failure(cl, 2, ModelKind::Gcn), Some(Failure::Timeout));
        // AdaQP OOM on As at x2.
        assert_eq!(System::AdaQp.failure(as_, 2, ModelKind::Gcn), Some(Failure::Oom));
        // SANCUS variants can't run GraphSAGE.
        assert!(System::DistGcn.failure(rt, 2, ModelKind::Sage).is_some());
        assert!(System::DistGcn.failure(rt, 2, ModelKind::Gcn).is_none());
        // CaPGNN never fails.
        for s in [2, 4, 8] {
            assert!(System::CaPGnn.failure(as_, s, ModelKind::Sage).is_none());
        }
    }

    #[test]
    fn run_preset_trains_on_a_cluster() {
        use crate::device::profile::DeviceKind;
        let ds = crate::graph::datasets::tiny(11);
        let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 3);
        let mut backend = crate::runtime::NativeBackend::new();
        let r = run_preset(System::CaPGnn, ModelKind::Gcn, 3, &ds, &cluster, &mut backend)
            .unwrap();
        assert_eq!(r.epoch_times.len(), 3);
        assert!(r.losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn ablations_toggle_features() {
        assert!(!Ablation::Jaca.config(5).use_rapa);
        assert!(Ablation::Jaca.config(5).use_cache);
        assert!(!Ablation::Rapa.config(5).use_cache);
        assert!(Ablation::Rapa.config(5).use_rapa);
        assert!(!Ablation::JacaRapa.config(5).pipeline);
        assert!(Ablation::Full.config(5).pipeline);
    }
}

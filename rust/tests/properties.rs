//! Property-based tests (hand-rolled sweep harness; proptest unavailable
//! offline): invariants of the graph substrate, partitioners, halo
//! machinery, RAPA and the cache policies under randomized inputs.

use capgnn::cache::{CachePolicy, PolicyKind};
use capgnn::device::profile::{DeviceKind, Gpu};
use capgnn::graph::delta::{DeltaGraph, Update};
use capgnn::graph::generator::{rmat, sbm, skewed_sbm};
use capgnn::graph::{Graph, SparseAdj};
use capgnn::partition::halo::{build_plan, expand_halo, halo_stats, overlap_ratio};
use capgnn::partition::rapa::{self, RapaConfig};
use capgnn::partition::Method;
use capgnn::util::Rng;
use std::collections::HashSet;

/// Run `f` across a seed sweep (our property-test loop).
fn forall_seeds(n: u64, mut f: impl FnMut(u64)) {
    for seed in 0..n {
        f(seed);
    }
}

fn random_graph(seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    match seed % 3 {
        0 => sbm(100 + rng.index(300), 2 + rng.index(5), 6.0, 2.0, &mut rng).0,
        1 => skewed_sbm(100 + rng.index(300), 2 + rng.index(5), 8.0, 3.0, 1.8, &mut rng).0,
        _ => rmat(8 + (seed % 2) as u32, 6.0, &mut rng),
    }
}

#[test]
fn prop_graph_invariants_hold_for_all_generators() {
    forall_seeds(24, |seed| {
        let g = random_graph(seed);
        g.check_invariants().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    });
}

#[test]
fn prop_partitions_cover_and_respect_bounds() {
    forall_seeds(18, |seed| {
        let g = random_graph(seed);
        let mut rng = Rng::new(seed ^ 77);
        let parts = 2 + (seed % 5) as usize;
        for method in [Method::Metis, Method::Random, Method::Fennel] {
            let ps = method.partition(&g, parts, &mut rng);
            ps.check(&g).unwrap();
            // Every vertex assigned exactly once is implied by the dense
            // assignment vector; check sizes sum.
            assert_eq!(ps.sizes().iter().sum::<usize>(), g.n());
        }
    });
}

#[test]
fn prop_halo_definition() {
    // H(Gi) = { v ∉ Gi : dist(v, Gi) ≤ hops }, exactly.
    forall_seeds(12, |seed| {
        let g = random_graph(seed);
        let mut rng = Rng::new(seed ^ 1234);
        let ps = Method::Random.partition(&g, 3, &mut rng);
        for p in 0..3u32 {
            let inner: HashSet<u32> = ps.members(p).into_iter().collect();
            let halo: HashSet<u32> = expand_halo(&g, &ps, p, 1).into_iter().collect();
            // Disjoint from inner.
            assert!(halo.is_disjoint(&inner), "seed {seed} part {p}");
            // Exactly the out-neighbors of the inner set.
            let mut expect = HashSet::new();
            for &v in &inner {
                for &u in g.nbrs(v) {
                    if !inner.contains(&u) {
                        expect.insert(u);
                    }
                }
            }
            assert_eq!(halo, expect, "seed {seed} part {p}");
        }
    });
}

#[test]
fn prop_overlap_ratio_equals_halo_membership_count() {
    forall_seeds(10, |seed| {
        let g = random_graph(seed);
        let mut rng = Rng::new(seed ^ 99);
        let parts = 2 + (seed % 4) as usize;
        let ps = Method::Metis.partition(&g, parts, &mut rng);
        let r = overlap_ratio(&g, &ps, 1);
        let mut counted = vec![0u32; g.n()];
        for p in 0..parts as u32 {
            for v in expand_halo(&g, &ps, p, 1) {
                counted[v as usize] += 1;
            }
        }
        assert_eq!(r, counted, "seed {seed}");
        // Σ R(v) = total halo with multiplicity.
        let st = halo_stats(&g, &ps, 1);
        assert_eq!(r.iter().map(|&x| x as usize).sum::<usize>(), st.total_halo);
    });
}

#[test]
fn prop_subgraph_plan_partitions_inner_vertices() {
    forall_seeds(10, |seed| {
        let g = random_graph(seed);
        let mut rng = Rng::new(seed ^ 5);
        let ps = Method::Fennel.partition(&g, 4, &mut rng);
        let plan = build_plan(&g, &ps);
        let mut seen = vec![false; g.n()];
        for sg in &plan.parts {
            // Inner ids sorted and unique across parts.
            for &v in &sg.global_ids[..sg.n_inner] {
                assert!(!seen[v as usize], "seed {seed}: vertex {v} owned twice");
                seen[v as usize] = true;
            }
            // halo_owner consistent with the assignment.
            for (hi, &v) in sg.halo_ids().iter().enumerate() {
                assert_eq!(sg.halo_owner[hi], ps.assignment[v as usize]);
                assert_ne!(sg.halo_owner[hi], sg.part);
            }
            sg.local.check_invariants().unwrap();
        }
        assert!(seen.iter().all(|&b| b), "seed {seed}: vertex unowned");
    });
}

#[test]
fn prop_rapa_never_touches_inner_and_reduces_spread() {
    forall_seeds(8, |seed| {
        let g = random_graph(seed);
        if g.n() < 60 {
            return;
        }
        let mut rng = Rng::new(seed ^ 31);
        let gpus = vec![
            Gpu::new(0, DeviceKind::Rtx3090, &mut rng),
            Gpu::new(1, DeviceKind::Rtx3060, &mut rng),
            Gpu::new(2, DeviceKind::Gtx1650, &mut rng),
        ];
        let res = rapa::run(&g, &gpus, &RapaConfig::default(), Method::Metis, &mut rng);
        // Full-batch invariant: every vertex trained exactly once.
        let total_inner: usize = res.plan.parts.iter().map(|p| p.n_inner).sum();
        assert_eq!(total_inner, g.n(), "seed {seed}");
        // λ spread never grows from first to last snapshot.
        let first = res.trace.first().unwrap().lambda_std;
        let last = res.trace.last().unwrap().lambda_std;
        assert!(last <= first + 1e-9, "seed {seed}: {first} -> {last}");
        // Halos only shrink.
        for (sg, &pruned) in res.plan.parts.iter().zip(&res.pruned) {
            let full = expand_halo(&g, &res.assignment, sg.part, 1).len();
            assert_eq!(full - pruned, sg.n_halo(), "seed {seed}");
        }
    });
}

#[test]
fn prop_cache_policies_never_exceed_capacity() {
    forall_seeds(12, |seed| {
        let mut rng = Rng::new(seed);
        for kind in [PolicyKind::Jaca, PolicyKind::Fifo, PolicyKind::Lru] {
            let cap = 1 + rng.index(32);
            let mut c = kind.build(cap);
            let universe = 1 + rng.index(128) as u64;
            for _ in 0..500 {
                let key = rng.next_below(universe);
                match rng.index(4) {
                    0 => {
                        let _ = c.insert(key);
                    }
                    1 => {
                        c.touch(key);
                    }
                    2 => {
                        c.remove(key);
                    }
                    _ => {
                        let _ = c.contains(key);
                    }
                }
                assert!(c.len() <= cap, "{} seed {seed}", kind.name());
            }
        }
    });
}

#[test]
fn prop_cache_insert_then_contains_unless_refused() {
    forall_seeds(10, |seed| {
        let mut rng = Rng::new(seed ^ 2);
        for kind in [PolicyKind::Jaca, PolicyKind::Fifo, PolicyKind::Lru] {
            let mut c = kind.build(8);
            for _ in 0..200 {
                let key = rng.next_below(64);
                c.set_priority(key, (key % 5) as u32 + 1);
                let outcome = c.insert(key);
                if outcome.stored() {
                    assert!(c.contains(key), "{} seed {seed}", kind.name());
                } else {
                    assert!(!c.contains(key), "{} seed {seed}", kind.name());
                }
                if let Some(victim) = outcome.victim() {
                    assert!(!c.contains(victim));
                }
            }
        }
    });
}

/// CSR structural invariants beyond `check_invariants`: monotone
/// offsets, strictly sorted (hence deduped) neighbor lists, no
/// self-loops, and symmetric adjacency.
fn assert_csr_canonical(g: &Graph, ctx: &str) {
    g.check_invariants().unwrap_or_else(|e| panic!("{ctx}: {e}"));
    for v in 0..g.n() as u32 {
        let nb = g.nbrs(v);
        for w in nb.windows(2) {
            assert!(w[0] < w[1], "{ctx}: vertex {v} neighbors unsorted/duplicated");
        }
        for &u in nb {
            assert_ne!(u, v, "{ctx}: self-loop at {v}");
            assert!(g.has_edge(u, v), "{ctx}: asymmetric arc {v}->{u}");
        }
    }
}

#[test]
fn prop_delta_mutations_keep_csr_canonical() {
    // Every mutation path — apply (inserts, deletes, redundant ops,
    // self-loops), compaction, snapshot — must land on a canonical CSR.
    forall_seeds(10, |seed| {
        let g = random_graph(seed);
        let n = g.n();
        let mut rng = Rng::new(seed ^ 0xde17a);
        let mut dg = DeltaGraph::new(g);
        for round in 0..6 {
            let mut batch = Vec::new();
            for _ in 0..10 {
                let u = rng.index(n) as u32;
                let v = if rng.index(8) == 0 { u } else { rng.index(n) as u32 };
                batch.push(if rng.index(2) == 0 {
                    Update::Insert(u, v)
                } else {
                    Update::Delete(u, v)
                });
            }
            let out = dg.apply(&batch).unwrap();
            // Touched endpoints are sorted, deduped and in range.
            for w in out.touched.windows(2) {
                assert!(w[0] < w[1], "seed {seed}: touched not sorted/deduped");
            }
            assert!(out.touched.iter().all(|&v| (v as usize) < n));
            assert_csr_canonical(&dg.snapshot(), &format!("seed {seed} round {round}"));
            if rng.index(2) == 0 {
                dg.compact();
                assert_csr_canonical(dg.base(), &format!("seed {seed} round {round} compacted"));
            }
        }
    });
}

#[test]
fn delta_compaction_boundary_sizes() {
    let mut rng = Rng::new(7);
    let g = sbm(80, 4, 6.0, 2.0, &mut rng).0;
    let n = g.n();

    // Empty delta: apply([]) then compact is a structural no-op.
    let mut dg = DeltaGraph::new(g.clone());
    dg.apply(&[]).unwrap();
    dg.compact();
    assert_eq!(dg.snapshot(), g, "empty delta must not change the graph");
    assert_eq!(dg.stats().depth, 0);

    // All-deleted vertex: strip vertex 0 of every edge, then kill and
    // rebuild the whole graph edge by edge.
    let mut dg = DeltaGraph::new(g.clone());
    let batch: Vec<Update> = g.nbrs(0).iter().map(|&v| Update::Delete(0, v)).collect();
    let out = dg.apply(&batch).unwrap();
    assert_eq!(out.deleted as usize, g.nbrs(0).len());
    dg.compact();
    assert_csr_canonical(dg.base(), "isolated vertex 0");
    assert!(dg.base().nbrs(0).is_empty(), "vertex 0 must be isolated");
    assert_eq!(dg.base().n(), n, "vertex universe is fixed");

    // Full teardown: delete every edge → empty CSR at full vertex count.
    let mut all: Vec<Update> = Vec::new();
    for u in 0..n as u32 {
        for &v in g.nbrs(u) {
            if u < v {
                all.push(Update::Delete(u, v));
            }
        }
    }
    let mut dg = DeltaGraph::new(g.clone());
    dg.apply(&all).unwrap();
    let empty = dg.snapshot();
    assert_eq!(empty.m(), 0, "all edges deleted");
    assert_eq!(empty.n(), n);
    assert_csr_canonical(&empty, "empty graph");

    // Full rebuild: reinsert the same edges → bitwise the original CSR.
    let rebuild: Vec<Update> = all
        .iter()
        .map(|d| {
            let (u, v) = d.endpoints();
            Update::Insert(u, v)
        })
        .collect();
    dg.apply(&rebuild).unwrap();
    dg.compact();
    assert_eq!(*dg.base(), g, "delete-all then insert-all must round-trip");
}

#[test]
fn prop_sparse_transpose_round_trips() {
    // The lazily built transpose holds exactly the forward entries with
    // rows and columns swapped, bit-for-bit, and both operators are
    // structurally canonical CSR (monotone indptr, sorted columns).
    forall_seeds(8, |seed| {
        let g = random_graph(seed);
        for adj in [SparseAdj::gcn_normalized(&g, g.n()), SparseAdj::sage_mean(&g, g.n())] {
            for m in [adj.fwd(), adj.transpose()] {
                assert_eq!(m.indptr.len(), adj.n() + 1);
                for w in m.indptr.windows(2) {
                    assert!(w[0] <= w[1], "seed {seed}: indptr not monotone");
                }
                for r in 0..m.n_rows() {
                    let cols = &m.indices[m.indptr[r] as usize..m.indptr[r + 1] as usize];
                    for w in cols.windows(2) {
                        assert!(w[0] < w[1], "seed {seed}: row {r} columns unsorted");
                    }
                }
            }
            let triplets = |m: &capgnn::graph::CsrMat, swap: bool| {
                let mut t = Vec::with_capacity(m.nnz());
                for r in 0..m.n_rows() {
                    for i in m.indptr[r] as usize..m.indptr[r + 1] as usize {
                        let (a, b) = if swap {
                            (m.indices[i], r as u32)
                        } else {
                            (r as u32, m.indices[i])
                        };
                        t.push((a, b, m.values[i].to_bits()));
                    }
                }
                t.sort_unstable();
                t
            };
            assert_eq!(
                triplets(adj.fwd(), false),
                triplets(adj.transpose(), true),
                "seed {seed}: transpose entry set mismatch"
            );
        }
    });
}

#[test]
fn prop_reorder_preserves_isomorphism_class() {
    forall_seeds(8, |seed| {
        let g = random_graph(seed);
        for perm in [
            capgnn::graph::reorder::bfs_order(&g),
            capgnn::graph::reorder::degree_order(&g),
        ] {
            let h = capgnn::graph::reorder::apply(&g, &perm);
            assert_eq!(g.n(), h.n());
            assert_eq!(g.m(), h.m());
            // Edge preservation under the permutation.
            for v in 0..g.n() as u32 {
                for &u in g.nbrs(v) {
                    assert!(h.has_edge(perm[v as usize], perm[u as usize]));
                }
            }
        }
    });
}

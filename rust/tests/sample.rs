//! Behavioral contract of the mini-batch neighbor-sampled trainer:
//! batch-schedule edge cases (partial tail, oversized batch), fanout
//! edge cases (fanout above the max degree, zero-degree seeds), cache
//! reuse across batches, and the `.cgr` round-trip acceptance path with
//! worker-count-invariant losses.

use capgnn::device::profile::DeviceKind;
use capgnn::dist::Cluster;
use capgnn::graph::datasets::tiny;
use capgnn::graph::{io, Dataset, DatasetSource, Graph, NodeData};
use capgnn::runtime::NativeBackend;
use capgnn::train::{SampledSession, TrainConfig, TrainMode};
use capgnn::util::Rng;

fn sampled_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        hidden: 16,
        layers: 2,
        lr: 0.05,
        mode: TrainMode::Sampled,
        batch_size: 32,
        fanout: vec![4, 3],
        ..TrainConfig::capgnn(epochs)
    }
}

fn cluster(workers: usize) -> Cluster {
    Cluster::homogeneous(DeviceKind::Rtx3090, workers, 7)
}

/// tiny(256 vertices) has a 60% train split → 154 train vertices; with
/// batch size 32 that is 4 full batches plus a partial tail of 26, and
/// every epoch reports the same batch count.
#[test]
fn partial_tail_batch_is_counted() {
    let ds = tiny(11);
    let n_train = ds.data.train_mask.iter().filter(|&&m| m).count();
    let mut cfg = sampled_cfg(2);
    if n_train % cfg.batch_size == 0 {
        cfg.batch_size -= 1; // force a partial tail whatever the split count
    }
    let expect = n_train.div_ceil(cfg.batch_size);
    assert!(n_train % cfg.batch_size != 0, "want a partial tail for this test");

    let cl = cluster(2);
    let mut backend = NativeBackend::new();
    let mut session = SampledSession::build(&ds, &cl, &mut backend, &cfg).unwrap();
    for _ in 0..cfg.epochs {
        let stats = session.run_epoch().unwrap();
        assert_eq!(stats.batches, expect);
        assert!(stats.loss.is_finite());
        assert!(stats.sampled_vertices > 0);
    }
    let report = session.finish().unwrap().0;
    assert_eq!(report.batches_per_epoch, expect);
    assert_eq!(report.epoch_touched.len(), cfg.epochs);
}

/// A batch size larger than the train set degenerates to one batch per
/// epoch — sampled full-batch — and still trains.
#[test]
fn oversized_batch_is_one_batch_per_epoch() {
    let ds = tiny(11);
    let n_train = ds.data.train_mask.iter().filter(|&&m| m).count();
    let mut cfg = sampled_cfg(2);
    cfg.batch_size = n_train * 10;

    let cl = cluster(2);
    let mut backend = NativeBackend::new();
    let mut session = SampledSession::build(&ds, &cl, &mut backend, &cfg).unwrap();
    let stats = session.run_epoch().unwrap();
    assert_eq!(stats.batches, 1);
    assert!(stats.loss.is_finite());
    let report = session.finish().unwrap().0;
    assert_eq!(report.batches_per_epoch, 1);
}

/// Fanout above the max degree: every vertex takes all of its neighbors
/// (without consuming RNG), so sampling degenerates to the full
/// neighborhood and still trains deterministically.
#[test]
fn fanout_above_max_degree_trains() {
    let ds = tiny(11);
    let max_deg = (0..ds.graph.n() as u32).map(|v| ds.graph.degree(v)).max().unwrap();
    let mut cfg = sampled_cfg(2);
    cfg.fanout = vec![max_deg + 7; cfg.layers];

    let cl = cluster(2);
    let mut backend = NativeBackend::new();
    let mut session = SampledSession::build(&ds, &cl, &mut backend, &cfg).unwrap();
    let a = session.run_epoch().unwrap();
    assert!(a.loss.is_finite());
    assert!(a.sampled_vertices > 0);

    // Same config twice from scratch → bit-identical epoch.
    let mut backend2 = NativeBackend::new();
    let mut session2 = SampledSession::build(&ds, &cl, &mut backend2, &cfg).unwrap();
    let b = session2.run_epoch().unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.sampled_vertices, b.sampled_vertices);
}

/// Zero-degree (isolated) seed vertices: their block is just themselves
/// (GCN keeps a self-loop; the loss stays finite) and training proceeds.
#[test]
fn zero_degree_seeds_train_with_finite_loss() {
    // 12 vertices: a 6-cycle plus 6 isolated vertices; every vertex is a
    // train vertex so batches hit the isolated ones.
    let n = 12usize;
    let edges: Vec<(u32, u32)> = (0..6u32).map(|i| (i, (i + 1) % 6)).collect();
    let graph = Graph::from_edges(n, &edges);
    let f_dim = 8usize;
    let mut rng = Rng::new(3);
    let features: Vec<f32> = (0..n * f_dim).map(|_| rng.f64() as f32 - 0.5).collect();
    let data = NodeData {
        features,
        f_dim,
        labels: (0..n as u32).map(|v| v % 4).collect(),
        num_classes: 4,
        train_mask: vec![true; n],
        val_mask: vec![false; n],
        test_mask: vec![false; n],
    };
    let ds = Dataset { name: "isolated", label: "Ty", graph, data };

    let mut cfg = sampled_cfg(3);
    cfg.batch_size = 4;
    cfg.fanout = vec![2, 2];
    let cl = cluster(2);
    let mut backend = NativeBackend::new();
    let mut session = SampledSession::build(&ds, &cl, &mut backend, &cfg).unwrap();
    for _ in 0..cfg.epochs {
        let stats = session.run_epoch().unwrap();
        assert!(stats.loss.is_finite(), "isolated seeds must not NaN the loss");
        assert_eq!(stats.batches, 3);
    }
    let report = session.finish().unwrap().0;
    assert!(report.losses.iter().all(|l| l.is_finite()));
}

/// JACA reuse across batches: hot halo vertices recur batch to batch, so
/// after the cold first touches the cache serves repeats — the hit rate
/// and saved bytes are strictly positive with more than one worker.
#[test]
fn cache_hit_rate_is_positive_across_batches() {
    let ds = tiny(11);
    let cfg = sampled_cfg(3);
    let cl = cluster(2);
    let mut backend = NativeBackend::new();
    let mut session = SampledSession::build(&ds, &cl, &mut backend, &cfg).unwrap();
    session.run_epochs(cfg.epochs).unwrap();
    let report = session.finish().unwrap().0;
    assert!(
        report.cache.hit_rate() > 0.0,
        "expected cache hits on recurring halo vertices, got {:?}",
        report.cache
    );
    assert!(report.bytes_saved > 0, "cache hits must save wire bytes");
    assert!(report.bytes_moved > 0, "cold misses must move wire bytes");
}

/// Acceptance path: ingest a `.cgr` dataset from disk and train sampled
/// end-to-end on 1/2/4 workers — losses and accuracies bit-identical at a
/// fixed seed regardless of worker count, with a nonzero cache hit rate
/// when workers exchange halo rows.
#[test]
fn cgr_round_trip_trains_identically_across_workers() {
    let dir = std::path::Path::new("target/test_sample");
    std::fs::create_dir_all(dir).unwrap();
    let path = dir.join("sampled_roundtrip.cgr");
    let twin = tiny(11);
    io::save_cgr(&path, &twin.graph, Some(&twin.data)).unwrap();

    let source = DatasetSource::parse(&format!("file:{}", path.display())).unwrap();
    let ds = source.build(42, 1.0).unwrap();
    assert_eq!(ds.graph.n(), twin.graph.n());

    let cfg = sampled_cfg(3);
    let mut reports = Vec::new();
    for workers in [1usize, 2, 4] {
        let cl = cluster(workers);
        let mut backend = NativeBackend::new();
        let mut session = SampledSession::build(&ds, &cl, &mut backend, &cfg).unwrap();
        session.run_epochs(cfg.epochs).unwrap();
        let report = session.finish().unwrap().0;
        assert!(report.losses.iter().all(|l| l.is_finite()), "workers={workers}");
        if workers > 1 {
            assert!(
                report.cache.hit_rate() > 0.0,
                "workers={workers}: sampled halo rows must hit the cache"
            );
        }
        reports.push(report);
    }
    for r in &reports[1..] {
        assert_eq!(reports[0].losses, r.losses, "losses must not depend on worker count");
        assert_eq!(reports[0].val_accs, r.val_accs);
        assert_eq!(reports[0].test_acc, r.test_acc);
    }
}

/// Config validation at build time: bad batch size or fanout shape is a
/// clear error, not a panic mid-epoch.
#[test]
fn build_rejects_bad_sampling_config() {
    let ds = tiny(11);
    let cl = cluster(2);
    let mut backend = NativeBackend::new();

    let mut cfg = sampled_cfg(1);
    cfg.batch_size = 0;
    assert!(SampledSession::build(&ds, &cl, &mut backend, &cfg).is_err());

    let mut cfg = sampled_cfg(1);
    cfg.fanout = vec![4]; // one entry for two layers
    assert!(SampledSession::build(&ds, &cl, &mut backend, &cfg).is_err());

    let mut cfg = sampled_cfg(1);
    cfg.fanout = vec![4, 0];
    assert!(SampledSession::build(&ds, &cl, &mut backend, &cfg).is_err());
}

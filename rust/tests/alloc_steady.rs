//! Zero-allocation contract of the native backend's hot loop.
//!
//! PR 4 replaced the per-call `scratch.clone()` / `d_out_grad.to_vec()` /
//! fresh `vec![]` pattern with a persistent scratch arena and caller-owned
//! output vectors. This binary holds exactly one test (so no sibling test
//! thread pollutes the counter) and wraps the global allocator in an
//! allocation counter: after a warmup pass sizes every buffer (and builds
//! the lazy CSR transpose), repeated `gcn_fwd/gcn_bwd/sage_fwd/sage_bwd`
//! calls must perform **zero** allocations.
//!
//! `ce_grad` is excluded: it returns a fresh `LossGrad` by design (one
//! small allocation per epoch per worker, not per layer).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use capgnn::graph::{Graph, SparseAdj};
use capgnn::runtime::{Backend, NativeBackend};
use capgnn::util::Rng;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn backend_steady_state_allocates_nothing() {
    let mut rng = Rng::new(21);
    let g = Graph::random(300, 1800, &mut rng);
    let n_pad = 512;
    let gcn_adj = SparseAdj::gcn_normalized(&g, n_pad);
    let sage_adj = SparseAdj::sage_mean(&g, n_pad);
    let (d_in, d_out) = (24usize, 24usize);
    let h: Vec<f32> = (0..n_pad * d_in).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() as f32).collect();
    let w2: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() as f32).collect();
    let dgrad: Vec<f32> = (0..n_pad * d_out).map(|_| rng.normal() as f32).collect();

    // Single-threaded SpMM: the scoped-thread dispatch of threads > 1
    // necessarily allocates per call (thread stacks); the arena contract
    // is about the serial hot loop every worker thread runs.
    let mut be = NativeBackend::new();
    let mut out = Vec::new();
    let (mut g_w, mut d_h) = (Vec::new(), Vec::new());
    let (mut g_ws, mut g_wn, mut sd_h) = (Vec::new(), Vec::new(), Vec::new());

    let pass = |be: &mut NativeBackend,
                    out: &mut Vec<f32>,
                    g_w: &mut Vec<f32>,
                    d_h: &mut Vec<f32>,
                    g_ws: &mut Vec<f32>,
                    g_wn: &mut Vec<f32>,
                    sd_h: &mut Vec<f32>| {
        for relu in [true, false] {
            be.gcn_fwd(n_pad, d_in, d_out, relu, &gcn_adj, &h, &w, out).unwrap();
            be.gcn_bwd(n_pad, d_in, d_out, relu, &gcn_adj, &h, &w, &dgrad, g_w, d_h)
                .unwrap();
            be.sage_fwd(n_pad, d_in, d_out, relu, &sage_adj, &h, &w, &w2, out).unwrap();
            be.sage_bwd(n_pad, d_in, d_out, relu, &sage_adj, &h, &w, &w2, &dgrad, g_ws,
                        g_wn, sd_h)
                .unwrap();
        }
    };

    // Warmup: sizes the arena and the output vectors, builds both lazy
    // transposes.
    for _ in 0..3 {
        pass(&mut be, &mut out, &mut g_w, &mut d_h, &mut g_ws, &mut g_wn, &mut sd_h);
    }

    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    for _ in 0..10 {
        pass(&mut be, &mut out, &mut g_w, &mut d_h, &mut g_ws, &mut g_wn, &mut sd_h);
    }
    let after = ALLOC_CALLS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "native backend must not allocate in steady state ({} allocations in 10 passes)",
        after - before
    );
    // The outputs are still real numbers, not stale garbage.
    assert!(out.iter().all(|v| v.is_finite()));
    assert!(d_h.iter().all(|v| v.is_finite()));
    assert!(sd_h.iter().all(|v| v.is_finite()));
}

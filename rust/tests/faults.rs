//! PR9 chaos matrix: deterministic fault injection across the transport,
//! training, and serving layers.
//!
//! The contract under test is *transparent recovery*: a run that hits
//! injected faults but recovers — link-layer retransmission for
//! corrupted/dropped frames, the `--max-retries` epoch budget for worker
//! panics and transient backend errors, checkpoint → kill → `--resume`
//! for process death — must be **bit-identical** to a clean run in its
//! losses, accuracies, and byte accounting. (Wall clocks and the fault
//! counters themselves legitimately differ.) The serving side has a
//! weaker, liveness-shaped contract: overload sheds with a typed error,
//! panicking workers respawn, and the server keeps answering.

use capgnn::device::profile::DeviceKind;
use capgnn::dist::Cluster;
use capgnn::fault::FaultPlan;
use capgnn::graph::datasets::{synthetic_node_data, tiny};
use capgnn::graph::{Dataset, Graph};
use capgnn::runtime::NativeBackend;
use capgnn::sample::Fanout;
use capgnn::serve::{ServeConfig, ServeError, Server};
use capgnn::train::{
    run_with, ExecMode, RunOptions, SampledSession, StrategyKind, TrainConfig, TrainMode,
    TrainReport,
};
use capgnn::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn tiny_cfg(epochs: usize) -> TrainConfig {
    TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(epochs) }
}

fn sampled_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        mode: TrainMode::Sampled,
        batch_size: 16,
        fanout: vec![4, 3],
        ..tiny_cfg(epochs)
    }
}

/// Arm a config with a parsed `--fault` plan; returns the plan too so
/// tests can assert which faults actually fired.
fn armed(cfg: &TrainConfig, spec: &str) -> (TrainConfig, Arc<FaultPlan>) {
    let fp = Arc::new(FaultPlan::parse(spec).expect("fault spec"));
    let mut cfg = cfg.clone();
    cfg.fault = Some(fp.clone());
    (cfg, fp)
}

/// One full run through the unified facade on a fixed dataset.
fn run_report(cfg: &TrainConfig, cluster: &Cluster, max_retries: usize) -> TrainReport {
    let ds = tiny(21);
    let mut backend = NativeBackend::new();
    run_with(
        &ds,
        cluster,
        &mut backend,
        cfg,
        RunOptions { max_retries, ..RunOptions::default() },
    )
    .expect("run")
    .report
}

/// The recovery parity criteria: numerics and byte accounting, bitwise.
/// Deliberately excludes wall clocks, simulated times and cache *stat
/// counters* (a retried epoch legitimately re-counts its cache checks),
/// which is exactly the PR9 acceptance bar.
fn assert_same_outcome(a: &TrainReport, b: &TrainReport, what: &str) {
    assert_eq!(a.losses, b.losses, "{what}: losses");
    assert_eq!(a.val_accs, b.val_accs, "{what}: val accs");
    assert_eq!(
        a.test_acc.to_bits(),
        b.test_acc.to_bits(),
        "{what}: test acc ({} vs {})",
        a.test_acc,
        b.test_acc
    );
    assert_eq!(a.bytes_moved, b.bytes_moved, "{what}: bytes moved");
    assert_eq!(a.bytes_saved, b.bytes_saved, "{what}: bytes saved");
    assert_eq!(a.cross_bytes_moved, b.cross_bytes_moved, "{what}: cross wire bytes");
    assert_eq!(a.cross_bytes_naive, b.cross_bytes_naive, "{what}: naive cross bytes");
    assert_eq!(a.broadcast_bytes, b.broadcast_bytes, "{what}: broadcast bytes");
}

/// Corrupted, dropped, and delayed frames are recovered *below* the
/// epoch level (CRC + bounded retransmission), so a heavily faulted run
/// needs no retry budget at all — across both strategies and both
/// executors on a two-machine cluster.
#[test]
fn link_faults_recover_bitwise_across_matrix() {
    let cluster = Cluster::preset("2M-2D").unwrap();
    for strategy in [StrategyKind::Halo, StrategyKind::OneHalfD] {
        for exec in [ExecMode::Sequential, ExecMode::Threaded] {
            let mut cfg = tiny_cfg(3);
            cfg.strategy = strategy;
            cfg.exec = exec;
            let what = format!("2M-2D {:?} {exec:?}", strategy);
            let clean = run_report(&cfg, &cluster, 0);
            let (fcfg, fp) = armed(&cfg, "seed=11,corrupt=0.4,drop=0.3,delay=0.3");
            let faulted = run_report(&fcfg, &cluster, 0);
            let c = fp.counters();
            assert!(
                fp.total_injected() > 0,
                "{what}: no faults fired — the matrix is not testing anything"
            );
            assert!(c.retries > 0, "{what}: faults fired but nothing retransmitted");
            assert_same_outcome(&clean, &faulted, &what);
        }
    }
    // On one machine no rows travel as frames, so link faults have no
    // surface to bite: the plan stays silent even at probability 1.
    let one_machine = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let (fcfg, fp) = armed(&tiny_cfg(2), "seed=11,corrupt=1.0,drop=1.0");
    let clean = run_report(&tiny_cfg(2), &one_machine, 0);
    let faulted = run_report(&fcfg, &one_machine, 0);
    assert_eq!(fp.total_injected(), 0, "1M cluster has no frames to fault");
    assert_same_outcome(&clean, &faulted, "1M link faults");
}

/// Worker panics and transient backend errors abort the epoch; with a
/// retry budget the purged-and-replayed epoch is bit-identical to one
/// that never faulted — on one and two machines, both executors. (On the
/// threaded executor the injected panic really unwinds a worker thread.)
#[test]
fn epoch_aborts_retry_bitwise() {
    let clusters = [
        ("1M-2D", Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7)),
        ("2M-2D", Cluster::preset("2M-2D").unwrap()),
    ];
    for (cname, cluster) in &clusters {
        for exec in [ExecMode::Sequential, ExecMode::Threaded] {
            for spec in ["seed=5,panic=1.0", "seed=5,backend=1.0"] {
                let mut cfg = tiny_cfg(3);
                cfg.exec = exec;
                let what = format!("{cname} {exec:?} {spec}");
                let clean = run_report(&cfg, cluster, 0);
                let (fcfg, fp) = armed(&cfg, spec);
                // Probability 1 faults every epoch's first attempt; one
                // retry per epoch recovers each.
                let faulted = run_report(&fcfg, cluster, 1);
                let c = fp.counters();
                assert!(
                    c.panics + c.backend_errs >= 3,
                    "{what}: expected one abort per epoch, saw {c:?}"
                );
                assert_same_outcome(&clean, &faulted, &what);
            }
        }
    }
}

/// Sticky faults ignore the attempt counter, so they exhaust any retry
/// budget — and the error says how many attempts were burned.
#[test]
fn sticky_faults_exhaust_the_retry_budget() {
    let ds = tiny(21);
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let (cfg, _) = armed(&tiny_cfg(3), "seed=5,backend=1.0,sticky=1");
    let mut backend = NativeBackend::new();
    let err = run_with(
        &ds,
        &cluster,
        &mut backend,
        &cfg,
        RunOptions { max_retries: 2, ..RunOptions::default() },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("after 3 attempt(s)"), "{err}");
    assert!(err.contains("backend"), "{err}");
}

/// Satellite (b): a `SampledSession` epoch that fails mid-stream (after
/// some mini-batch SGD steps already landed) rolls back to its entry
/// state, so the retried epoch is bit-identical to a fresh session's
/// epoch 0 — model updates and byte accounting included.
#[test]
fn sampled_retried_epoch_matches_fresh_run() {
    let ds = tiny(21);
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    for exec in [ExecMode::Sequential, ExecMode::Threaded] {
        let mut cfg = sampled_cfg(2);
        cfg.exec = exec;
        let what = format!("sampled {exec:?}");

        // Clean reference epoch.
        let mut cb = NativeBackend::new();
        let mut clean = SampledSession::build(&ds, &cluster, &mut cb, &cfg).unwrap();
        let want = clean.run_epoch().unwrap();

        // Faulted: the first attempt aborts (transient backend error on
        // every worker), the second replays the same epoch.
        let (fcfg, fp) = armed(&cfg, "seed=9,backend=1.0");
        let mut fb = NativeBackend::new();
        let mut s = SampledSession::build(&ds, &cluster, &mut fb, &fcfg).unwrap();
        assert!(s.run_epoch().is_err(), "{what}: probability-1 fault must abort");
        assert_eq!(s.epoch(), 0, "{what}: a failed epoch must not advance the counter");
        fp.begin_attempt(1);
        let got = s.run_epoch().unwrap();
        assert_eq!(got.epoch, 0, "{what}");
        assert_eq!(got.loss.to_bits(), want.loss.to_bits(), "{what}: loss");
        assert_eq!(got.val_acc.to_bits(), want.val_acc.to_bits(), "{what}: val acc");
        assert_eq!(got.bytes_moved, want.bytes_moved, "{what}: bytes moved");
        assert_eq!(got.bytes_saved, want.bytes_saved, "{what}: bytes saved");
        assert_eq!(got.batches, want.batches, "{what}: batch count");
        assert_eq!(got.sampled_vertices, want.sampled_vertices, "{what}: block vertices");
    }

    // Whole-run parity through the facade: every epoch faults once and
    // retries once; the final report and artifact match a clean run.
    let cfg = sampled_cfg(3);
    let mut cb = NativeBackend::new();
    let clean = run_with(&ds, &cluster, &mut cb, &cfg, RunOptions::default()).unwrap();
    let (fcfg, _) = armed(&cfg, "seed=9,backend=1.0");
    let mut fb = NativeBackend::new();
    let faulted = run_with(
        &ds,
        &cluster,
        &mut fb,
        &fcfg,
        RunOptions { max_retries: 1, ..RunOptions::default() },
    )
    .unwrap();
    assert_same_outcome(&clean.report, &faulted.report, "sampled facade retry");
    for (a, b) in clean.model.model.weights.iter().zip(&faulted.model.model.weights) {
        for (ra, rb) in a.iter().zip(b) {
            assert!(
                ra.iter().zip(rb).all(|(x, y)| x.to_bits() == y.to_bits()),
                "sampled facade retry: weights diverged"
            );
        }
    }
}

/// Checkpoint → kill → resume through the CLI-facing `run_with` path: a
/// run killed after its epoch-3 checkpoint and resumed from the `.cgk`
/// file finishes with bit-identical numerics, bytes, and weights to an
/// uninterrupted run. A checkpoint from a different config is refused by
/// fingerprint.
#[test]
fn checkpoint_kill_resume_is_bit_identical() {
    let ds = tiny(22);
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let mut cfg = tiny_cfg(6);
    cfg.refresh_interval = 2; // exercise the one-shot refresh flag across the boundary
    let path = std::env::temp_dir()
        .join(format!("capgnn_faults_resume_{}.cgk", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();

    let mut b0 = NativeBackend::new();
    let clean = run_with(&ds, &cluster, &mut b0, &cfg, RunOptions::default()).unwrap();

    // First life: 3 epochs, checkpoint written after the 3rd, then the
    // process "dies" (the session is simply dropped).
    let mut cfg3 = cfg.clone();
    cfg3.epochs = 3;
    let mut b1 = NativeBackend::new();
    run_with(
        &ds,
        &cluster,
        &mut b1,
        &cfg3,
        RunOptions {
            checkpoint_every: Some(3),
            checkpoint_path: Some(path_s.clone()),
            ..RunOptions::default()
        },
    )
    .unwrap();

    // Second life: resume the 6-epoch config from the artifact. The
    // fingerprint ignores `epochs`, so interrupted and full configs match.
    let mut b2 = NativeBackend::new();
    let resumed = run_with(
        &ds,
        &cluster,
        &mut b2,
        &cfg,
        RunOptions { resume: Some(path_s.clone()), ..RunOptions::default() },
    )
    .unwrap();
    assert_eq!(resumed.report.losses.len(), 6, "resume must keep the full history");
    assert_same_outcome(&clean.report, &resumed.report, "kill + resume");
    for (a, b) in clean.model.model.weights.iter().zip(&resumed.model.model.weights) {
        for (ra, rb) in a.iter().zip(b) {
            assert!(
                ra.iter().zip(rb).all(|(x, y)| x.to_bits() == y.to_bits()),
                "kill + resume: weights diverged"
            );
        }
    }

    // A config with different numerics must be refused, not resumed.
    let mut other = cfg.clone();
    other.seed += 1;
    let mut b3 = NativeBackend::new();
    let err = run_with(
        &ds,
        &cluster,
        &mut b3,
        &other,
        RunOptions { resume: Some(path_s), ..RunOptions::default() },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("fingerprint"), "{err}");
    std::fs::remove_file(&path).ok();
}

// ---- Serving degradation ------------------------------------------------

fn serve_dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    for v in 1..n as u32 {
        edges.push((v - 1, v));
    }
    for _ in 0..n * 4 {
        let a = rng.index(n) as u32;
        let b = rng.index(n) as u32;
        if a != b {
            edges.push((a, b));
        }
    }
    let graph = Graph::from_edges(n, &edges);
    let data = synthetic_node_data(&graph, 6, 8, seed);
    Dataset { name: "faults-serve", label: "Fs", graph, data }
}

/// Admission control under overload: once `max_queue` requests are
/// pending, further submissions fail with the typed
/// [`ServeError::Overloaded`] — and the queued requests still complete.
#[test]
fn serve_overload_sheds_typed_and_stays_consistent() {
    let ds = serve_dataset(128, 13);
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let mut backend = NativeBackend::new();
    let cfg = TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(2) };
    let model = run_with(&ds, &cluster, &mut backend, &cfg, RunOptions::default())
        .unwrap()
        .model;
    let scfg = ServeConfig {
        fanout: Fanout(vec![4, 4]),
        cache_capacity: 32,
        prepopulate: 0,
        workers: 1,
        max_batch: 1024,
        max_wait_us: 60_000_000, // hold everything until shutdown drains
        max_queue: 3,
        ..ServeConfig::new(2)
    };
    let mut h = Server::start(&ds, model, &scfg).unwrap();
    for v in 0..3 {
        h.submit(v).unwrap();
    }
    assert_eq!(h.queue_depth(), 3);
    let err = h.submit(3).unwrap_err();
    let shed = err
        .downcast_ref::<ServeError>()
        .unwrap_or_else(|| panic!("untyped overload error: {err}"));
    let ServeError::Overloaded { depth, limit } = shed;
    assert_eq!((*depth, *limit), (3, 3));
    assert_eq!(h.shed(), 1);
    let rep = h.shutdown().unwrap();
    assert_eq!(rep.shed, 1);
    assert_eq!(rep.requests, 3, "shed submissions never entered the pipeline");
    assert_eq!(rep.responses, 3, "queued requests must still be answered");
}

/// A panicking worker is respawned in place and the server keeps
/// answering — bounded-time liveness, verified with real timeouts.
#[test]
fn serve_worker_panic_respawns_and_keeps_answering() {
    let ds = serve_dataset(128, 17);
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let mut backend = NativeBackend::new();
    let cfg = TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(2) };
    let model = run_with(&ds, &cluster, &mut backend, &cfg, RunOptions::default())
        .unwrap()
        .model;
    let scfg = ServeConfig {
        fanout: Fanout(vec![4, 4]),
        cache_capacity: 32,
        prepopulate: 0,
        workers: 1,
        max_batch: 1,
        max_wait_us: 100,
        fault: Some(Arc::new(FaultPlan::parse("seed=3,panic=1.0").unwrap())),
        ..ServeConfig::new(2)
    };
    let mut h = Server::start(&ds, model, &scfg).unwrap();
    for v in 0..5 {
        h.submit(v).unwrap();
    }
    // The first dequeued batch dies with its worker (a non-sticky panic
    // fires once per worker lifetime); the respawned worker must answer
    // the remaining four within the timeout.
    let mut got = 0;
    while got < 4 {
        match h.recv_timeout(Duration::from_secs(30)) {
            Some(_) => got += 1,
            None => panic!("server went silent after a worker panic ({got} of 4)"),
        }
    }
    let rep = h.shutdown().unwrap();
    assert_eq!(rep.panics, 1, "exactly one injected panic");
    assert_eq!(rep.respawns, 1, "the dead worker must be respawned");
    assert_eq!(rep.requests, 5);
    assert_eq!(rep.responses, 4, "only the in-flight batch is lost");
}

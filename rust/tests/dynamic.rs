//! Delta-vs-rebuild equivalence suite (PR 10): every observable of a
//! dynamic-graph run — CSR bytes, propagation operators (including the
//! lazily built transpose), training losses, cache counters, serve
//! outputs — must be **bitwise identical** whether the evolving graph is
//! maintained incrementally ([`GraphMode::Delta`], an overlay log with
//! periodic compaction) or rebuilt from scratch at every update point
//! ([`GraphMode::Rebuild`]). The randomized generator exercises inserts,
//! deletes, duplicate edges, self-loops, and isolated-vertex birth and
//! death across executors × caching × strategies × cluster shapes.

use capgnn::dist::Cluster;
use capgnn::graph::datasets::tiny;
use capgnn::graph::delta::{parse_updates, DeltaGraph, Update, UpdateBatch};
use capgnn::graph::{Graph, SparseAdj};
use capgnn::runtime::NativeBackend;
use capgnn::sample::Fanout;
use capgnn::serve::serve_output;
use capgnn::train::{
    run_dynamic, DynamicConfig, DynamicOutcome, ExecMode, GraphMode, StrategyKind, TrainConfig,
};
use capgnn::util::Rng;
use std::collections::BTreeSet;

/// Seeded batch generator: random inserts/deletes with deliberate
/// duplicate edges and self-loops mixed in.
fn random_batches(n: usize, batches: usize, per_batch: usize, rng: &mut Rng) -> Vec<UpdateBatch> {
    (0..batches)
        .map(|_| {
            let mut batch = UpdateBatch::new();
            for _ in 0..per_batch {
                let u = rng.index(n) as u32;
                // ~1 in 8 updates is a self-loop on purpose.
                let v = if rng.index(8) == 0 { u } else { rng.index(n) as u32 };
                let up = if rng.index(2) == 0 {
                    Update::Insert(u, v)
                } else {
                    Update::Delete(u, v)
                };
                batch.push(up);
                // ~1 in 4 updates is immediately duplicated (redundant).
                if rng.index(4) == 0 {
                    batch.push(up);
                }
            }
            batch
        })
        .collect()
}

/// The reference arm at the graph level: a normalized undirected edge
/// set with last-write-wins update semantics, rebuilt via `from_edges`.
fn scratch_apply(n: usize, edges: &mut BTreeSet<(u32, u32)>, batch: &[Update]) {
    for up in batch {
        let (u, v) = up.endpoints();
        assert!((u as usize) < n && (v as usize) < n);
        if u == v {
            continue;
        }
        let e = (u.min(v), u.max(v));
        match up {
            Update::Insert(..) => {
                edges.insert(e);
            }
            Update::Delete(..) => {
                edges.remove(&e);
            }
        }
    }
}

fn scratch_graph(n: usize, edges: &BTreeSet<(u32, u32)>) -> Graph {
    let list: Vec<(u32, u32)> = edges.iter().copied().collect();
    Graph::from_edges(n, &list)
}

/// Order-independent FNV-1a digest over (vertex, output bits) — the
/// serve-equivalence fingerprint.
fn serve_digest(rows: &[(u32, Vec<f32>)]) -> u64 {
    let mut items: Vec<(u32, Vec<u32>)> = rows
        .iter()
        .map(|(v, out)| (*v, out.iter().map(|x| x.to_bits()).collect()))
        .collect();
    items.sort();
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for (v, bits) in items {
        mix(v as u64);
        for b in bits {
            mix(b as u64);
        }
    }
    h
}

fn tiny_cfg(epochs: usize) -> TrainConfig {
    TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(epochs) }
}

fn run_both(
    seed: u64,
    cluster: &Cluster,
    cfg: &TrainConfig,
    dyn_cfg: &DynamicConfig,
) -> (DynamicOutcome, DynamicOutcome) {
    let ds = tiny(seed);
    let mut b1 = NativeBackend::new();
    let a = run_dynamic(&ds, cluster, &mut b1, cfg, dyn_cfg, GraphMode::Delta).unwrap();
    let mut b2 = NativeBackend::new();
    let b = run_dynamic(&ds, cluster, &mut b2, cfg, dyn_cfg, GraphMode::Rebuild).unwrap();
    (a, b)
}

/// Assert every run-level observable matches bitwise between the arms.
fn assert_equivalent(a: &DynamicOutcome, b: &DynamicOutcome, label: &str) {
    assert_eq!(a.report.losses.len(), b.report.losses.len(), "{label}: epoch count");
    for (i, (x, y)) in a.report.losses.iter().zip(&b.report.losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: loss[{i}]");
    }
    assert_eq!(a.report.test_acc.to_bits(), b.report.test_acc.to_bits(), "{label}: test acc");
    assert_eq!(a.report.bytes_moved, b.report.bytes_moved, "{label}: bytes moved");
    assert_eq!(a.report.bytes_saved, b.report.bytes_saved, "{label}: bytes saved");
    assert_eq!(a.report.cache, b.report.cache, "{label}: cache stats");
    assert_eq!(a.invalidated, b.invalidated, "{label}: invalidated rows");
    assert_eq!(a.repartitions, b.repartitions, "{label}: repartitions");
    assert_eq!(a.touched, b.touched, "{label}: touched sets");
    assert_eq!(a.drift, b.drift, "{label}: drift trace");
    assert_eq!(a.stats.inserts, b.stats.inserts, "{label}: inserts");
    assert_eq!(a.stats.deletes, b.stats.deletes, "{label}: deletes");
    assert_eq!(a.stats.redundant, b.stats.redundant, "{label}: redundant");
    assert_eq!(a.stats.self_loops, b.stats.self_loops, "{label}: self-loops");
    let wa = &a.model.model.weights;
    let wb = &b.model.model.weights;
    assert_eq!(wa.len(), wb.len(), "{label}: layer count");
    for (la, lb) in wa.iter().zip(wb) {
        assert_eq!(la.len(), lb.len(), "{label}: matrices per layer");
        for (ma, mb) in la.iter().zip(lb) {
            assert_eq!(ma.len(), mb.len(), "{label}: weight matrix shape");
            for (x, y) in ma.iter().zip(mb) {
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: final weights");
            }
        }
    }
}

#[test]
fn delta_snapshot_tracks_scratch_rebuild_under_random_updates() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed ^ 0xd1f7);
        let base = tiny(seed).graph;
        let n = base.n();
        let mut dg = DeltaGraph::new(base.clone());
        let mut edges: BTreeSet<(u32, u32)> = BTreeSet::new();
        for u in 0..n as u32 {
            for &v in base.nbrs(u) {
                if u < v {
                    edges.insert((u, v));
                }
            }
        }
        for round in 0..8 {
            let batch = random_batches(n, 1, 12, &mut rng).pop().unwrap();
            dg.apply(&batch).unwrap();
            scratch_apply(n, &mut edges, &batch);
            let snap = dg.snapshot();
            let scratch = scratch_graph(n, &edges);
            assert_eq!(snap, scratch, "seed {seed} round {round}: CSR mismatch");
            snap.check_invariants().unwrap();
            // The propagation operators (and their lazily built
            // transposes) are bitwise equal too — the trainer's actual
            // inputs, not just the raw CSR.
            for (sa, sb) in [
                (SparseAdj::gcn_normalized(&snap, n), SparseAdj::gcn_normalized(&scratch, n)),
                (SparseAdj::sage_mean(&snap, n), SparseAdj::sage_mean(&scratch, n)),
            ] {
                assert_eq!(sa.fwd(), sb.fwd(), "seed {seed} round {round}: fwd operator");
                assert_eq!(
                    sa.transpose(),
                    sb.transpose(),
                    "seed {seed} round {round}: transposed operator"
                );
            }
            // Compaction at a random point must be invisible.
            if rng.index(3) == 0 {
                dg.compact();
                assert_eq!(dg.snapshot(), scratch, "seed {seed} round {round}: post-compact");
            }
        }
    }
}

#[test]
fn training_is_bitwise_identical_across_the_matrix() {
    // Executors × caching × strategies, alternating cluster shapes: the
    // full dynamic run (losses, bytes, cache counters, invalidations,
    // drift decisions, final weights) may not depend on how the graph
    // was maintained.
    let mut rng = Rng::new(0x9210);
    let combos = [
        (ExecMode::Sequential, true, StrategyKind::Halo),
        (ExecMode::Sequential, true, StrategyKind::OneHalfD),
        (ExecMode::Sequential, false, StrategyKind::Halo),
        (ExecMode::Sequential, false, StrategyKind::OneHalfD),
        (ExecMode::Threaded, true, StrategyKind::Halo),
        (ExecMode::Threaded, true, StrategyKind::OneHalfD),
        (ExecMode::Threaded, false, StrategyKind::Halo),
        (ExecMode::Threaded, false, StrategyKind::OneHalfD),
    ];
    for (i, &(exec, use_cache, strategy)) in combos.iter().enumerate() {
        let preset = if i % 2 == 0 { "1M-4D" } else { "2M-2D" };
        let cluster = Cluster::preset(preset).unwrap();
        let mut cfg = tiny_cfg(5);
        cfg.exec = exec;
        cfg.use_cache = use_cache;
        cfg.strategy = strategy;
        let n = tiny(31).graph.n();
        let dyn_cfg = DynamicConfig {
            batches: random_batches(n, 2, 10, &mut rng),
            update_every: 2,
            ..DynamicConfig::default()
        };
        let (a, b) = run_both(31, &cluster, &cfg, &dyn_cfg);
        let label = format!("{}/{}/cache={}/{}", preset, exec.name(), use_cache, strategy.name());
        assert_equivalent(&a, &b, &label);
        assert_eq!(a.report.losses.len(), 5, "{label}: full epoch budget");
        if !use_cache {
            assert_eq!(a.invalidated, 0, "{label}: nothing cached, nothing invalidated");
        }
    }
}

#[test]
fn invalidation_drops_resident_rows_on_touched_vertices() {
    // Deterministically touch every connected vertex: delete each
    // vertex's first edge. Any row resident in the carried cache belongs
    // to a vertex with at least one (old) edge, so it must be dropped —
    // invalidations > 0 whenever fills > 0.
    let ds = tiny(33);
    let g = &ds.graph;
    let mut batch = UpdateBatch::new();
    for u in 0..g.n() as u32 {
        if let Some(&v) = g.nbrs(u).first() {
            batch.push(Update::Delete(u, v));
        }
    }
    let cluster = Cluster::preset("1M-4D").unwrap();
    let cfg = tiny_cfg(4);
    let dyn_cfg = DynamicConfig {
        batches: vec![batch],
        update_every: 2,
        ..DynamicConfig::default()
    };
    let mut backend = NativeBackend::new();
    let out = run_dynamic(&ds, &cluster, &mut backend, &cfg, &dyn_cfg, GraphMode::Delta).unwrap();
    assert!(out.report.cache.fills > 0, "the cache must have been exercised");
    assert!(out.invalidated > 0, "stale resident rows must be dropped");
    assert_eq!(
        out.report.cache.invalidations, out.invalidated,
        "the carried cache's counter must match the driver's total"
    );
    // Every touched vertex had an edge deleted, and deleting a vertex's
    // only edge makes it isolated — the graph still trains.
    assert_eq!(out.report.losses.len(), 4);
    assert!(out.stats.deletes > 0);
}

#[test]
fn serve_outputs_and_digests_match_after_updates() {
    let ds = tiny(35);
    let n = ds.graph.n();
    let mut rng = Rng::new(0x5e12);
    let dyn_cfg = DynamicConfig {
        batches: random_batches(n, 3, 8, &mut rng),
        update_every: 1,
        compact_every: 2,
        ..DynamicConfig::default()
    };
    let cluster = Cluster::preset("2M-2D").unwrap();
    let cfg = tiny_cfg(4);
    let (a, b) = run_both(35, &cluster, &cfg, &dyn_cfg);
    assert_equivalent(&a, &b, "serve-pretrain");

    // Reconstruct the final graph both ways and serve over it.
    let mut dg = DeltaGraph::new(ds.graph.clone());
    let mut edges: BTreeSet<(u32, u32)> = BTreeSet::new();
    for u in 0..n as u32 {
        for &v in ds.graph.nbrs(u) {
            if u < v {
                edges.insert((u, v));
            }
        }
    }
    for batch in &dyn_cfg.batches {
        dg.apply(batch).unwrap();
        scratch_apply(n, &mut edges, batch);
    }
    let final_delta = dg.snapshot();
    let final_scratch = scratch_graph(n, &edges);
    assert_eq!(final_delta, final_scratch, "final graphs must agree");

    let fanout = Fanout(vec![4; cfg.layers]);
    let vertices: Vec<u32> = (0..12).map(|_| rng.index(n) as u32).collect();
    let mut rows_a = Vec::new();
    let mut rows_b = Vec::new();
    let mut be = NativeBackend::new();
    for &v in &vertices {
        let oa = serve_output(&final_delta, &ds.data, &a.model.model, &fanout, 7, v, &mut be)
            .unwrap();
        let ob = serve_output(&final_scratch, &ds.data, &b.model.model, &fanout, 7, v, &mut be)
            .unwrap();
        for (x, y) in oa.iter().zip(&ob) {
            assert_eq!(x.to_bits(), y.to_bits(), "serve output for vertex {v}");
        }
        rows_a.push((v, oa));
        rows_b.push((v, ob));
    }
    assert_eq!(serve_digest(&rows_a), serve_digest(&rows_b), "serve digests");
}

#[test]
fn compaction_schedule_is_invisible_to_results() {
    let ds = tiny(37);
    let n = ds.graph.n();
    let mut rng = Rng::new(0xc0);
    let batches = random_batches(n, 4, 6, &mut rng);
    let cluster = Cluster::preset("1M-4D").unwrap();
    let cfg = tiny_cfg(5);
    let mut outs = Vec::new();
    for compact_every in [0usize, 1, 2, 100] {
        let dyn_cfg = DynamicConfig {
            batches: batches.clone(),
            update_every: 1,
            compact_every,
            ..DynamicConfig::default()
        };
        let mut backend = NativeBackend::new();
        outs.push(
            run_dynamic(&ds, &cluster, &mut backend, &cfg, &dyn_cfg, GraphMode::Delta).unwrap(),
        );
    }
    let first = &outs[0];
    for (i, o) in outs.iter().enumerate().skip(1) {
        assert_equivalent(first, o, &format!("compact schedule {i}"));
    }
    // The schedules really differed: never vs every batch.
    assert_eq!(outs[0].stats.compactions, 0);
    assert_eq!(outs[1].stats.compactions, 4);
}

#[test]
fn update_file_round_trips_through_the_parser() {
    // The CLI path (`--updates file:`) and the programmatic path feed
    // the same driver; a parsed file must behave like the literal
    // batches it encodes.
    let text = "# deltas\n+ 0 9\n- 0 1\n---\n+ 3 3\n+ 0 9\n- 250 251\n";
    let parsed = parse_updates(text).unwrap();
    let literal = vec![
        vec![Update::Insert(0, 9), Update::Delete(0, 1)],
        vec![Update::Insert(3, 3), Update::Insert(0, 9), Update::Delete(250, 251)],
    ];
    assert_eq!(parsed, literal);
    let cluster = Cluster::preset("1M-4D").unwrap();
    let cfg = tiny_cfg(3);
    let mk = |batches: Vec<UpdateBatch>| DynamicConfig {
        batches,
        update_every: 1,
        ..DynamicConfig::default()
    };
    let ds = tiny(39);
    let mut b1 = NativeBackend::new();
    let a = run_dynamic(&ds, &cluster, &mut b1, &cfg, &mk(parsed), GraphMode::Delta).unwrap();
    let mut b2 = NativeBackend::new();
    let b = run_dynamic(&ds, &cluster, &mut b2, &cfg, &mk(literal), GraphMode::Rebuild).unwrap();
    assert_equivalent(&a, &b, "parsed vs literal");
    // The second batch's self-loop and duplicate insert were counted.
    assert_eq!(a.stats.self_loops, 1);
    assert!(a.stats.redundant >= 1);
}

//! Serving-path integration tests: the determinism contract (same
//! vertex ⇒ bit-identical output across batches, workers, and cache
//! hit-vs-miss), micro-batcher flush behaviour, shutdown, and the
//! `.cgm` artifact round trip.

use capgnn::device::profile::DeviceKind;
use capgnn::dist::Cluster;
use capgnn::graph::datasets::synthetic_node_data;
use capgnn::graph::{Dataset, Graph};
use capgnn::model::TrainedModel;
use capgnn::runtime::NativeBackend;
use capgnn::sample::Fanout;
use capgnn::serve::{
    run_driver, serve_output, zipf_workload, Pacing, Response, ServeConfig, Server,
    ServerHandle, WorkloadConfig,
};
use capgnn::train::{run, TrainConfig};
use capgnn::util::Rng;
use std::collections::HashMap;
use std::time::Duration;

fn make_dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    for v in 1..n as u32 {
        edges.push((v - 1, v));
    }
    for _ in 0..n * 4 {
        let a = rng.index(n) as u32;
        let b = rng.index(n) as u32;
        if a != b {
            edges.push((a, b));
        }
    }
    let graph = Graph::from_edges(n, &edges);
    let data = synthetic_node_data(&graph, 6, 8, seed);
    Dataset { name: "serve-it", label: "Sv", graph, data }
}

/// Train a small model on the dataset through the unified facade.
fn trained(ds: &Dataset) -> TrainedModel {
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let cfg = TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(3) };
    let mut backend = NativeBackend::new();
    let (_report, model) = run(ds, &cluster, &mut backend, &cfg).unwrap();
    model
}

fn serve_cfg(cache: usize, prepopulate: usize) -> ServeConfig {
    ServeConfig {
        fanout: Fanout(vec![4, 4]),
        cache_capacity: cache,
        prepopulate,
        ..ServeConfig::new(2)
    }
}

fn drain(handle: &ServerHandle, n: usize) -> Vec<Response> {
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        match handle.recv_timeout(Duration::from_secs(30)) {
            Some(r) => out.push(r),
            None => panic!("timed out waiting for {n} responses (got {})", out.len()),
        }
    }
    out
}

fn bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|x| x.to_bits()).collect()
}

/// Cache miss then cache hit must produce the same bytes.
#[test]
fn miss_then_hit_is_bit_identical() {
    let ds = make_dataset(128, 11);
    let model = trained(&ds);
    let mut cfg = serve_cfg(64, 0); // nothing warmed: first touch misses
    cfg.workers = 1;
    let mut h = Server::start(&ds, model, &cfg).unwrap();
    h.submit(5).unwrap();
    let first = drain(&h, 1).remove(0);
    h.submit(5).unwrap();
    let second = drain(&h, 1).remove(0);
    assert!(!first.cache_hit, "cold cache must miss");
    assert!(second.cache_hit, "second request must hit");
    assert_eq!(bits(&first.output), bits(&second.output));
    let rep = h.shutdown().unwrap();
    assert_eq!(rep.responses, 2);
    assert_eq!(rep.cache.hits, 1);
}

/// Worker count is unobservable in the outputs.
#[test]
fn outputs_identical_across_worker_counts() {
    let ds = make_dataset(128, 12);
    let model = trained(&ds);
    let vertices: Vec<u32> = (0..40u32).collect();
    let mut per_count: Vec<HashMap<u32, Vec<u32>>> = Vec::new();
    for workers in [1usize, 2, 4] {
        let mut cfg = serve_cfg(32, 16);
        cfg.workers = workers;
        let mut h = Server::start(&ds, model.clone(), &cfg).unwrap();
        for &v in &vertices {
            h.submit(v).unwrap();
        }
        let resps = drain(&h, vertices.len());
        let mut by_vertex = HashMap::new();
        for r in resps {
            by_vertex.insert(r.vertex, bits(&r.output));
        }
        h.shutdown().unwrap();
        per_count.push(by_vertex);
    }
    for other in &per_count[1..] {
        assert_eq!(&per_count[0], other, "outputs changed with worker count");
    }
}

/// Caching (and pre-population) is unobservable in the outputs, and the
/// warmed cache actually hits.
#[test]
fn cache_is_unobservable_but_hits() {
    let ds = make_dataset(128, 13);
    let model = trained(&ds);
    let workload = zipf_workload(
        &ds.graph,
        &WorkloadConfig { requests: 200, zipf_s: 1.2, hot_ranks: 32, seed: 9 },
    );

    let mut uncached = Server::start(&ds, model.clone(), &serve_cfg(0, 0)).unwrap();
    let a = run_driver(&mut uncached, &workload, Pacing::Closed { concurrency: 8 }).unwrap();
    let ra = uncached.shutdown().unwrap();
    assert_eq!(ra.cache.hits, 0, "zero-capacity cache cannot hit");

    let mut cached = Server::start(&ds, model, &serve_cfg(64, 32)).unwrap();
    let b = run_driver(&mut cached, &workload, Pacing::Closed { concurrency: 8 }).unwrap();
    let rb = cached.shutdown().unwrap();

    assert!(a.consistent && b.consistent);
    assert_eq!(a.output_digest, b.output_digest, "cache changed the answers");
    assert!(b.hit_rate > 0.0, "warmed cache never hit: {b:?}");
    assert!(rb.cache.prepopulated > 0);
}

/// A single straggler is flushed by the deadline, not stuck waiting for
/// a full batch.
#[test]
fn deadline_flushes_a_single_straggler() {
    let ds = make_dataset(64, 14);
    let model = trained(&ds);
    let mut cfg = serve_cfg(0, 0);
    cfg.max_batch = 64;
    cfg.max_wait_us = 10_000;
    let mut h = Server::start(&ds, model, &cfg).unwrap();
    h.submit(3).unwrap();
    let r = h
        .recv_timeout(Duration::from_secs(10))
        .expect("straggler must be answered within the deadline");
    assert_eq!(r.vertex, 3);
    let rep = h.shutdown().unwrap();
    assert!(rep.deadline_flushes >= 1, "{rep:?}");
    assert_eq!(rep.max_batch_seen, 1);
}

/// A burst larger than max_batch splits into several full batches.
#[test]
fn oversized_burst_splits_into_bounded_batches() {
    let ds = make_dataset(64, 15);
    let model = trained(&ds);
    let mut cfg = serve_cfg(0, 0);
    cfg.max_batch = 8;
    cfg.workers = 2;
    let mut h = Server::start(&ds, model, &cfg).unwrap();
    for i in 0..50u32 {
        h.submit(i % 64).unwrap();
    }
    let resps = drain(&h, 50);
    let mut per_batch: HashMap<u64, usize> = HashMap::new();
    for r in &resps {
        *per_batch.entry(r.batch).or_insert(0) += 1;
    }
    for (batch, count) in &per_batch {
        assert!(*count <= 8, "batch {batch} carried {count} > max_batch requests");
    }
    let rep = h.shutdown().unwrap();
    assert_eq!(rep.responses, 50);
    assert!(rep.max_batch_seen <= 8);
    assert!(rep.batches >= 7, "50 requests need at least ceil(50/8) batches");
}

/// Shutting down an idle server terminates cleanly with zero traffic.
#[test]
fn empty_queue_shutdown_is_clean() {
    let ds = make_dataset(64, 16);
    let model = trained(&ds);
    let h = Server::start(&ds, model, &serve_cfg(16, 4)).unwrap();
    let rep = h.shutdown().unwrap();
    assert_eq!(rep.requests, 0);
    assert_eq!(rep.responses, 0);
    assert_eq!(rep.batches, 0);
    assert!(rep.cache.prepopulated > 0, "warmup still ran");
}

/// Saving and reloading the artifact must not change a single bit of
/// any served output.
#[test]
fn cgm_round_trip_serves_identically() {
    let ds = make_dataset(96, 17);
    let model = trained(&ds);
    let path = std::env::temp_dir()
        .join(format!("capgnn_serve_rt_{}.cgm", std::process::id()));
    model.save(&path).unwrap();
    let loaded = TrainedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.seed, model.seed);

    let fan = Fanout(vec![4, 4]);
    let mut be = NativeBackend::new();
    for v in 0..10u32 {
        let a = serve_output(&ds.graph, &ds.data, &model.model, &fan, 42, v, &mut be).unwrap();
        let b = serve_output(&ds.graph, &ds.data, &loaded.model, &fan, 42, v, &mut be).unwrap();
        assert_eq!(bits(&a), bits(&b), "vertex {v} differs after round trip");
    }
}

/// Out-of-range vertices are rejected at submit time.
#[test]
fn submit_rejects_out_of_range_vertices() {
    let ds = make_dataset(64, 18);
    let model = trained(&ds);
    let mut h = Server::start(&ds, model, &serve_cfg(0, 0)).unwrap();
    assert!(h.submit(64).is_err());
    assert!(h.submit(63).is_ok());
    drain(&h, 1);
    let rep = h.shutdown().unwrap();
    assert_eq!(rep.requests, 1, "rejected submits are not counted");
}

/// The closed-loop driver completes a Zipfian stream with consistent
/// outputs and a strictly positive cross-request hit rate.
#[test]
fn closed_loop_driver_is_consistent_with_hits() {
    let ds = make_dataset(192, 19);
    let model = trained(&ds);
    let workload = zipf_workload(
        &ds.graph,
        &WorkloadConfig { requests: 300, zipf_s: 1.1, hot_ranks: 48, seed: 4 },
    );
    let mut h = Server::start(&ds, model, &serve_cfg(96, 48)).unwrap();
    let d = run_driver(&mut h, &workload, Pacing::Closed { concurrency: 12 }).unwrap();
    let rep = h.shutdown().unwrap();
    assert!(d.consistent, "determinism violated");
    assert_eq!(d.sent, 300);
    assert_eq!(d.received, 300);
    assert!(d.hit_rate > 0.0, "no cross-request hits: {d:?}");
    assert_eq!(rep.compute_errors, 0);
    assert_eq!(rep.responses, 300);
}

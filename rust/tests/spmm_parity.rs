//! PR 4 parity contract: the CSR SpMM backend is **bit-exact** against
//! the seed repo's dense compute path.
//!
//! Two layers of evidence:
//! 1. Kernel parity — `gcn_fwd/gcn_bwd/sage_fwd/sage_bwd` on random
//!    graphs match [`dense_oracle`] (the seed loops kept verbatim) to the
//!    bit, across 1/2/4 aggregation threads × GCN/SAGE × relu on/off.
//! 2. End-to-end — a full threaded training run on the 2M-2D preset
//!    produces exactly the seed losses: a `DenseOracleBackend` that
//!    densifies the operator and replays the seed math epoch for epoch
//!    must agree with the sparse backend on every loss, at any
//!    aggregation thread count.

use capgnn::dist::Cluster;
use capgnn::graph::{Graph, SparseAdj};
use capgnn::model::ModelKind;
use capgnn::runtime::backend::LossGrad;
use capgnn::runtime::native::dense_oracle;
use capgnn::runtime::{Backend, NativeBackend};
use capgnn::train::{ExecMode, Session, TrainConfig};
use capgnn::util::Rng;
use anyhow::Result;

fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}[{i}]: sparse {a} vs dense {b}");
    }
}

/// The satellite matrix: 1/2/4 aggregation threads × gcn/sage × relu
/// on/off, all four backend ops, zero tolerance.
#[test]
fn backend_ops_bit_exact_vs_dense_oracle() {
    let mut rng = Rng::new(42);
    for &(n, m) in &[(60usize, 240usize), (173, 1200)] {
        let g = Graph::random(n, m, &mut rng);
        let n_pad = n.next_power_of_two(); // non-trivial padded tail rows
        let gcn_adj = SparseAdj::gcn_normalized(&g, n_pad);
        let sage_adj = SparseAdj::sage_mean(&g, n_pad);
        let gcn_dense = gcn_adj.to_dense();
        let sage_dense = sage_adj.to_dense();
        let (di, do_) = (13usize, 7usize);
        let h = rand_vec(&mut rng, n_pad * di);
        let w = rand_vec(&mut rng, di * do_);
        let w2 = rand_vec(&mut rng, di * do_);
        let dgrad = rand_vec(&mut rng, n_pad * do_);
        for relu in [true, false] {
            // The oracle is thread-oblivious: compute it once per case.
            let want_gf = dense_oracle::gcn_fwd(n_pad, di, do_, relu, &gcn_dense, &h, &w);
            let (want_gw, want_gdh) =
                dense_oracle::gcn_bwd(n_pad, di, do_, relu, &gcn_dense, &h, &w, &dgrad);
            let want_sf =
                dense_oracle::sage_fwd(n_pad, di, do_, relu, &sage_dense, &h, &w, &w2);
            let (want_sws, want_swn, want_sdh) = dense_oracle::sage_bwd(
                n_pad, di, do_, relu, &sage_dense, &h, &w, &w2, &dgrad,
            );
            for threads in [1usize, 2, 4] {
                let what = format!("n={n} relu={relu} threads={threads}");
                let mut be = NativeBackend::with_threads(threads);
                let mut out = Vec::new();
                be.gcn_fwd(n_pad, di, do_, relu, &gcn_adj, &h, &w, &mut out).unwrap();
                assert_bits(&out, &want_gf, &format!("gcn_fwd {what}"));
                let (mut g_w, mut d_h) = (Vec::new(), Vec::new());
                be.gcn_bwd(n_pad, di, do_, relu, &gcn_adj, &h, &w, &dgrad, &mut g_w,
                           &mut d_h)
                    .unwrap();
                assert_bits(&g_w, &want_gw, &format!("gcn_bwd gW {what}"));
                assert_bits(&d_h, &want_gdh, &format!("gcn_bwd dH {what}"));
                let mut sout = Vec::new();
                be.sage_fwd(n_pad, di, do_, relu, &sage_adj, &h, &w, &w2, &mut sout)
                    .unwrap();
                assert_bits(&sout, &want_sf, &format!("sage_fwd {what}"));
                let (mut g_ws, mut g_wn, mut sd_h) = (Vec::new(), Vec::new(), Vec::new());
                be.sage_bwd(n_pad, di, do_, relu, &sage_adj, &h, &w, &w2, &dgrad,
                            &mut g_ws, &mut g_wn, &mut sd_h)
                    .unwrap();
                assert_bits(&g_ws, &want_sws, &format!("sage_bwd gWs {what}"));
                assert_bits(&g_wn, &want_swn, &format!("sage_bwd gWn {what}"));
                assert_bits(&sd_h, &want_sdh, &format!("sage_bwd dH {what}"));
            }
        }
    }
}

/// The seed repo's dense backend, reconstructed: densify the operator
/// and replay the exact pre-PR4 per-layer loops. Slow and O(n²) — it
/// exists so end-to-end runs can be checked against seed numerics.
struct DenseOracleBackend {
    /// ce_grad is unchanged from the seed — reuse the native one.
    inner: NativeBackend,
}

impl DenseOracleBackend {
    fn new() -> DenseOracleBackend {
        DenseOracleBackend { inner: NativeBackend::new() }
    }
}

impl Backend for DenseOracleBackend {
    fn gcn_fwd(&mut self, n: usize, d_in: usize, d_out: usize, relu: bool,
               adj: &SparseAdj, h: &[f32], w: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let a = adj.to_dense();
        *out = dense_oracle::gcn_fwd(n, d_in, d_out, relu, &a, h, w);
        Ok(())
    }

    fn gcn_bwd(&mut self, n: usize, d_in: usize, d_out: usize, relu: bool,
               adj: &SparseAdj, h: &[f32], w: &[f32], d_out_grad: &[f32],
               g_w: &mut Vec<f32>, d_h: &mut Vec<f32>) -> Result<()> {
        let a = adj.to_dense();
        let (gw, dh) = dense_oracle::gcn_bwd(n, d_in, d_out, relu, &a, h, w, d_out_grad);
        *g_w = gw;
        *d_h = dh;
        Ok(())
    }

    fn sage_fwd(&mut self, n: usize, d_in: usize, d_out: usize, relu: bool,
                adj: &SparseAdj, h: &[f32], w_self: &[f32], w_neigh: &[f32],
                out: &mut Vec<f32>) -> Result<()> {
        let a = adj.to_dense();
        *out = dense_oracle::sage_fwd(n, d_in, d_out, relu, &a, h, w_self, w_neigh);
        Ok(())
    }

    fn sage_bwd(&mut self, n: usize, d_in: usize, d_out: usize, relu: bool,
                adj: &SparseAdj, h: &[f32], w_self: &[f32], w_neigh: &[f32],
                d_out_grad: &[f32], g_w_self: &mut Vec<f32>, g_w_neigh: &mut Vec<f32>,
                d_h: &mut Vec<f32>) -> Result<()> {
        let a = adj.to_dense();
        let (gs, gn, dh) =
            dense_oracle::sage_bwd(n, d_in, d_out, relu, &a, h, w_self, w_neigh, d_out_grad);
        *g_w_self = gs;
        *g_w_neigh = gn;
        *d_h = dh;
        Ok(())
    }

    fn ce_grad(&mut self, n: usize, c: usize,
               logits: &[f32], y: &[f32], mask: &[f32]) -> Result<LossGrad> {
        self.inner.ce_grad(n, c, logits, y, mask)
    }

    fn fork(&self) -> Option<Box<dyn Backend + Send>> {
        Some(Box::new(DenseOracleBackend::new()))
    }

    fn name(&self) -> &'static str {
        "dense-oracle"
    }
}

fn tiny_cfg(epochs: usize) -> TrainConfig {
    TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(epochs) }
}

fn run_report(
    backend: &mut dyn Backend,
    cluster: &Cluster,
    cfg: &TrainConfig,
) -> capgnn::train::TrainReport {
    let ds = capgnn::graph::datasets::tiny(11);
    let mut session = Session::build(&ds, cluster, backend, cfg).unwrap();
    session.run_epochs(cfg.epochs).unwrap();
    session.finish().unwrap().0
}

/// End-to-end seed check: `ExecMode::Threaded` on the 2M-2D preset
/// produces losses bit-identical to the dense seed path — the sparse
/// refactor changed the representation, not one bit of the training
/// trajectory. Aggregation threads don't change it either.
#[test]
fn threaded_2m2d_losses_unchanged_from_seed() {
    let cluster = Cluster::preset("2M-2D").unwrap();
    let mut cfg = tiny_cfg(3);
    cfg.exec = ExecMode::Threaded;

    let mut seed = DenseOracleBackend::new();
    let want = run_report(&mut seed, &cluster, &cfg);

    let mut sparse = NativeBackend::new();
    let got = run_report(&mut sparse, &cluster, &cfg);
    assert_eq!(got.losses, want.losses, "sparse vs seed losses (threaded, 2M-2D)");
    assert_eq!(got.val_accs, want.val_accs);
    assert_eq!(got.test_acc, want.test_acc);
    assert_eq!(got.bytes_moved, want.bytes_moved);
    assert_eq!(got.cross_bytes_moved, want.cross_bytes_moved);

    let mut sparse4 = NativeBackend::with_threads(4);
    let got4 = run_report(&mut sparse4, &cluster, &cfg);
    assert_eq!(got4.losses, want.losses, "agg threads must not change losses");
    assert_eq!(got4.test_acc, want.test_acc);
}

/// Same contract for GraphSAGE (two-matrix backward) on a single-machine
/// cluster, sequential executor.
#[test]
fn sage_session_matches_seed_dense_path() {
    use capgnn::device::profile::DeviceKind;
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let mut cfg = tiny_cfg(3);
    cfg.model = ModelKind::Sage;

    let mut seed = DenseOracleBackend::new();
    let want = run_report(&mut seed, &cluster, &cfg);
    let mut sparse = NativeBackend::with_threads(2);
    let got = run_report(&mut sparse, &cluster, &cfg);
    assert_eq!(got.losses, want.losses, "sage sparse vs seed losses");
    assert_eq!(got.val_accs, want.val_accs);
    assert_eq!(got.test_acc, want.test_acc);
}

//! Integration tests: the full stack composed end-to-end — partitioner →
//! RAPA → JACA cache → exchange → backend → trainer — plus cross-backend
//! consistency (native rust vs AOT XLA artifacts).

use capgnn::baselines::{Ablation, System};
use capgnn::device::profile::{DeviceKind, Gpu, GpuGroup};
use capgnn::device::topology::Topology;
use capgnn::dist::Cluster;
use capgnn::graph::datasets::tiny;
use capgnn::graph::spec_by_name;
use capgnn::model::ModelKind;
use capgnn::runtime::{Backend, Manifest, NativeBackend, XlaBackend};
use capgnn::train::{run, EarlyStopping, Session, TrainConfig, TrainReport};
use capgnn::util::Rng;

fn gpus(n: usize, seed: u64) -> Vec<Gpu> {
    let mut rng = Rng::new(seed);
    (0..n).map(|i| Gpu::new(i, DeviceKind::Rtx3090, &mut rng)).collect()
}

/// One-call training through the unified `train::run` facade (the
/// report half; the model artifact is exercised in `serve.rs`).
fn run_report(
    ds: &capgnn::graph::Dataset,
    gpus: &[Gpu],
    topo: &Topology,
    backend: &mut dyn Backend,
    cfg: &TrainConfig,
) -> anyhow::Result<TrainReport> {
    let cluster = Cluster::from_parts(gpus.to_vec(), topo.clone())?;
    Ok(run(ds, &cluster, backend, cfg)?.0)
}

fn tiny_cfg(epochs: usize) -> TrainConfig {
    TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(epochs) }
}

fn have_artifacts() -> bool {
    Manifest::load(&Manifest::default_dir()).is_ok()
}

/// The determinism contract: same seed ⇒ bit-identical report.
#[test]
fn training_is_deterministic() {
    let ds = tiny(1);
    let g = gpus(2, 3);
    let topo = Topology::pcie_pairs(2);
    let cfg = tiny_cfg(8);
    let mut b1 = NativeBackend::new();
    let mut b2 = NativeBackend::new();
    let r1 = run_report(&ds, &g, &topo, &mut b1, &cfg).unwrap();
    let r2 = run_report(&ds, &g, &topo, &mut b2, &cfg).unwrap();
    assert_eq!(r1.losses, r2.losses);
    assert_eq!(r1.val_accs, r2.val_accs);
    assert_eq!(r1.bytes_moved, r2.bytes_moved);
}

/// Native and XLA backends must agree on the training trajectory (they
/// implement the same math; fp reassociation allows small drift).
#[test]
fn xla_and_native_backends_agree() {
    if !have_artifacts() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let ds = tiny(2);
    let g = gpus(2, 4);
    let topo = Topology::pcie_pairs(2);
    let cfg = tiny_cfg(6);
    let mut nat = NativeBackend::new();
    let mut xla = XlaBackend::from_default_dir().unwrap();
    let rn = run_report(&ds, &g, &topo, &mut nat, &cfg).unwrap();
    let rx = run_report(&ds, &g, &topo, &mut xla, &cfg).unwrap();
    for (i, (a, b)) in rn.losses.iter().zip(&rx.losses).enumerate() {
        assert!(
            (a - b).abs() < 5e-3 * (1.0 + a.abs()),
            "epoch {i}: native loss {a} xla loss {b}"
        );
    }
    // Identical cache/communication behaviour (independent of backend).
    assert_eq!(rn.bytes_moved, rx.bytes_moved);
    assert_eq!(rn.cache.checks, rx.cache.checks);
}

/// Every system preset runs end-to-end on every model it supports.
#[test]
fn all_systems_run_both_models() {
    let ds = tiny(3);
    let g = gpus(2, 5);
    let topo = Topology::pcie_pairs(2);
    for system in capgnn::baselines::ALL_SYSTEMS {
        for model in [ModelKind::Gcn, ModelKind::Sage] {
            if !system.supports_sage() && model == ModelKind::Sage {
                continue;
            }
            let mut cfg = system.config(4, ds.data.f_dim);
            cfg.model = model;
            cfg.hidden = 16;
            cfg.layers = 2;
            let mut backend = NativeBackend::new();
            let r = run_report(&ds, &g, &topo, &mut backend, &cfg)
                .unwrap_or_else(|e| panic!("{} {} failed: {e}", system.name(), model.name()));
            assert_eq!(r.epoch_times.len(), 4);
            assert!(r.losses.iter().all(|l| l.is_finite()));
        }
    }
}

/// Every ablation arm runs and the comm ordering matches Table 8's shape:
/// Vanilla ≥ (+JACA | +RAPA) ≥ +JACA+RAPA ≥ full-with-pipe (visible comm).
#[test]
fn ablation_comm_ordering() {
    let ds = spec_by_name("Rt").unwrap().build_scaled(9, 0.15);
    let g = GpuGroup::by_name("x4").unwrap().instantiate(&mut Rng::new(6));
    let topo = Topology::pcie_pairs(4);
    let mut comm = std::collections::HashMap::new();
    for arm in capgnn::baselines::ABLATIONS {
        let cfg = arm.config(6);
        let mut backend = NativeBackend::new();
        let r = run_report(&ds, &g, &topo, &mut backend, &cfg).unwrap();
        comm.insert(arm.name(), r.total_comm());
    }
    let vanilla = comm["Vanilla"];
    assert!(comm["+JACA"] < vanilla, "JACA must cut comm: {comm:?}");
    assert!(comm["+RAPA"] < vanilla, "RAPA must cut comm: {comm:?}");
    assert!(
        comm["+JACA+RAPA"] <= comm["+JACA"] * 1.05,
        "combining should not regress: {comm:?}"
    );
    assert!(
        comm["+JACA+RAPA+Pipe."] <= comm["+JACA+RAPA"] * 1.05,
        "pipeline hides comm: {comm:?}"
    );
    let _ = Ablation::Full;
}

/// The staged Session must be numerically identical to the one-call
/// `train::run` facade (same seed, same config).
#[test]
fn session_matches_train_shim() {
    let ds = tiny(1);
    let g = gpus(2, 3);
    let topo = Topology::pcie_pairs(2);
    let cfg = tiny_cfg(8);
    let mut b1 = NativeBackend::new();
    let r1 = run_report(&ds, &g, &topo, &mut b1, &cfg).unwrap();

    let cluster = Cluster::from_parts(g.clone(), topo.clone()).unwrap();
    let mut b2 = NativeBackend::new();
    let mut session = Session::build(&ds, &cluster, &mut b2, &cfg).unwrap();
    let mut last = None;
    for _ in 0..cfg.epochs {
        last = Some(session.run_epoch().unwrap());
    }
    let r2 = session.finish().unwrap().0;
    assert_eq!(r1.losses, r2.losses);
    assert_eq!(r1.val_accs, r2.val_accs);
    assert_eq!(r1.bytes_moved, r2.bytes_moved);
    assert_eq!(r1.test_acc, r2.test_acc);
    let st = last.unwrap();
    assert_eq!(st.epoch, 7);
    assert_eq!(st.loss, r2.losses[7]);
}

/// Early stopping through the observer hook halts a session.
#[test]
fn early_stopping_halts_training() {
    let ds = tiny(2);
    let cluster = Cluster::from_parts(gpus(2, 4), Topology::pcie_pairs(2)).unwrap();
    let mut backend = NativeBackend::new();
    let mut session = Session::build(&ds, &cluster, &mut backend, &tiny_cfg(50)).unwrap();
    // min_delta = ∞ ⇒ no improvement ever counts ⇒ stop at patience+1.
    let mut stop = EarlyStopping::new(2, f32::INFINITY);
    let ran = session.run(50, &mut stop).unwrap();
    assert_eq!(ran, 3);
    assert_eq!(stop.stopped_at, Some(2));
    let report = session.finish().unwrap().0;
    assert_eq!(report.epoch_times.len(), 3);
}

/// Multi-machine cluster training composes with every preset cluster.
#[test]
fn distributed_presets_run() {
    let ds = tiny(4);
    for name in ["1M-4D", "2M-2D", "2M-4D"] {
        let cluster = Cluster::preset(name).unwrap();
        let mut backend = NativeBackend::new();
        let cfg = tiny_cfg(4);
        let r = capgnn::dist::train_distributed(&ds, &cluster, &mut backend, &cfg)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(r.epochs_per_sec > 0.0);
        assert!(r.report.losses.iter().all(|l| l.is_finite()));
    }
}

/// Failure injection: pathological inputs must not panic.
#[test]
fn degenerate_inputs_survive() {
    // Graph with isolated vertices and a single component of 3.
    let mut ds = tiny(5);
    // Single worker (no communication at all).
    let g = gpus(1, 7);
    let topo = Topology::pcie_pairs(1);
    let cfg = tiny_cfg(3);
    let mut backend = NativeBackend::new();
    let r = run_report(&ds, &g, &topo, &mut backend, &cfg).unwrap();
    assert_eq!(r.bytes_moved, 0);

    // Zero cache capacity with caching "on" — works, just never hits.
    let g2 = gpus(2, 8);
    let topo2 = Topology::pcie_pairs(2);
    let mut cfg2 = tiny_cfg(3);
    cfg2.capacity = capgnn::train::CapacityMode::Fixed { local: 0, global: 0 };
    let r2 = run_report(&ds, &g2, &topo2, &mut backend, &cfg2).unwrap();
    assert_eq!(r2.cache.local_hits + r2.cache.global_hits, 0);
    assert!(r2.losses.iter().all(|l| l.is_finite()));

    // More partitions than sensible (8 workers on 256 vertices).
    let g3 = gpus(8, 9);
    let topo3 = Topology::pcie_pairs(8);
    let r3 = run_report(&ds, &g3, &topo3, &mut backend, &tiny_cfg(2)).unwrap();
    assert!(r3.losses[1].is_finite());
    ds.name = "tiny";
    let _ = System::CaPGnn;
}

/// Bounded staleness: infrequent refresh must still converge on the twin
/// (Theorem 1's empirical counterpart), and refresh=1 matches Vanilla's
/// numerics exactly.
#[test]
fn staleness_bounded_convergence() {
    let ds = tiny(6);
    let g = gpus(2, 10);
    let topo = Topology::pcie_pairs(2);

    let mut stale = tiny_cfg(40);
    stale.refresh_interval = 10; // halo embeddings up to 10 epochs old
    let mut backend = NativeBackend::new();
    let r = run_report(&ds, &g, &topo, &mut backend, &stale).unwrap();
    assert!(
        r.losses.last().unwrap() < &(r.losses[0] * 0.7),
        "stale training must still converge: {:?} -> {:?}",
        r.losses[0],
        r.losses.last()
    );
    assert!(r.best_val_acc() > 0.5);

    // refresh=1: every non-static halo row fetched fresh every epoch ⇒
    // numerics identical to cache-off Vanilla (only static layer-0
    // features come from the cache, with identical values).
    let mut fresh = tiny_cfg(5);
    fresh.refresh_interval = 1;
    let mut vanilla = tiny_cfg(5);
    vanilla.use_cache = false;
    let rf = run_report(&ds, &g, &topo, &mut backend, &fresh).unwrap();
    let rv = run_report(&ds, &g, &topo, &mut backend, &vanilla).unwrap();
    for (a, b) in rf.losses.iter().zip(&rv.losses) {
        assert!((a - b).abs() < 1e-6, "fresh {a} vanilla {b}");
    }
}

//! Direct coverage for `cache/capacity.rs` (Algorithm 1) and
//! `graph/reorder.rs` from outside the crate, plus the
//! eviction-vs-invalidation interaction on a capacity-sized two-level
//! cache: an eviction is capacity pressure, an invalidation is a
//! correctness obligation, and the counters must never blur.

use capgnn::cache::twolevel::Hit;
use capgnn::cache::{cal_capacity, key_of, CapacityInput, PolicyKind, TwoLevelCache};
use capgnn::graph::generator::{sbm, skewed_sbm};
use capgnn::graph::reorder::{apply, bfs_order, degree_order, locality_cost};
use capgnn::graph::Graph;
use capgnn::partition::halo::build_plan;
use capgnn::partition::{Method, SubgraphPlan};
use capgnn::util::Rng;

fn plan(seed: u64, parts: usize) -> SubgraphPlan {
    let mut rng = Rng::new(seed);
    let (g, _) = skewed_sbm(350, parts, 8.0, 3.0, 1.6, &mut rng);
    let ps = Method::Metis.partition(&g, parts, &mut rng);
    build_plan(&g, &ps)
}

fn input(parts: usize) -> CapacityInput {
    CapacityInput {
        top_k: usize::MAX,
        gpu_mem_mib: vec![64.0; parts],
        gpu_reserved_mib: 1.0,
        cpu_mem_mib: 512.0,
        cpu_reserved_mib: 8.0,
        layer_dims: vec![32, 16, 16],
    }
}

#[test]
fn heterogeneous_memory_yields_heterogeneous_capacities() {
    let p = plan(11, 3);
    let mut inp = input(3);
    // One starved device, one tight, one roomy.
    let row = capgnn::cache::capacity::row_bytes(&inp.layer_dims) as f64;
    inp.gpu_reserved_mib = 0.0;
    // 10.5 rows of budget → floor lands robustly on 10 despite the
    // MiB round-trip in the arithmetic.
    inp.gpu_mem_mib = vec![0.0, 10.5 * row / (1024.0 * 1024.0), 64.0];
    let cap = cal_capacity(&p, &inp);
    assert_eq!(cap.gpu[0], 0, "no memory, no capacity");
    assert_eq!(cap.gpu[1], 10.min(p.parts[1].n_halo()), "memory-bounded");
    assert_eq!(cap.gpu[2], p.parts[2].n_halo(), "halo-bounded");
}

#[test]
fn reserved_memory_exceeding_available_clamps_to_zero() {
    let p = plan(13, 4);
    let mut inp = input(4);
    inp.gpu_reserved_mib = 1_000.0;
    inp.cpu_reserved_mib = 10_000.0;
    let cap = cal_capacity(&p, &inp);
    assert!(cap.gpu.iter().all(|&c| c == 0));
    assert_eq!(cap.cpu, 0);
}

#[test]
fn top_k_shrinks_both_levels_monotonically() {
    let p = plan(17, 4);
    let mut prev_cpu = 0;
    let mut prev_gpu_total = 0;
    for k in [1usize, 4, 16, 64, usize::MAX] {
        let mut inp = input(4);
        inp.top_k = k;
        let cap = cal_capacity(&p, &inp);
        let gpu_total: usize = cap.gpu.iter().sum();
        assert!(gpu_total >= prev_gpu_total, "gpu capacity must grow with k");
        assert!(cap.cpu >= prev_cpu, "cpu capacity must grow with k");
        assert!(cap.gpu.iter().all(|&c| c <= k), "per-part candidates capped at k");
        prev_cpu = cap.cpu;
        prev_gpu_total = gpu_total;
    }
}

#[test]
fn capacity_sized_cache_evicts_then_invalidates_without_blurring_counters() {
    // Size a two-level cache straight from Algorithm 1 with a deliberately
    // tiny per-GPU budget, overfill it so evictions happen, then
    // invalidate and check the two counters tell different stories.
    let p = plan(19, 2);
    let mut inp = input(2);
    let row = capgnn::cache::capacity::row_bytes(&inp.layer_dims) as f64;
    inp.gpu_reserved_mib = 0.0;
    inp.gpu_mem_mib = vec![4.5 * row / (1024.0 * 1024.0); 2]; // 4 rows per GPU
    let cap = cal_capacity(&p, &inp);
    let slots = cap.gpu[0];
    assert!(slots > 0 && slots <= 4, "tiny budget, got {slots}");

    let mut cache = TwoLevelCache::new(PolicyKind::Lru, &cap.gpu, cap.cpu);
    // Overfill worker 0 with 10 distinct vertex rows at layer 0.
    for v in 0..10u32 {
        cache.fill(0, key_of(0, v), vec![v as f32; 4], 0);
    }
    let evicted_before = cache.stats.local_evictions;
    assert!(evicted_before > 0, "10 fills into a {slots}-slot LRU must evict");
    assert_eq!(cache.local_len(0), slots);
    assert_eq!(cache.stats.invalidations, 0, "no invalidation yet");

    // Invalidate every vertex we ever filled, across layers 0..=2.
    let all: Vec<u32> = (0..10).collect();
    let dropped = cache.invalidate_vertices(&all, 2);
    // Only the still-resident rows count — never the earlier evictions.
    assert!(dropped >= slots as u64, "the {slots} resident local rows must drop");
    assert_eq!(cache.stats.invalidations, dropped);
    assert_eq!(
        cache.stats.local_evictions, evicted_before,
        "invalidation must not masquerade as eviction"
    );
    assert_eq!(cache.local_len(0), 0, "worker 0 fully invalidated");
    for v in 0..10u32 {
        assert_eq!(cache.lookup(0, key_of(0, v)), Hit::Miss, "vertex {v} still resident");
    }
}

#[test]
fn invalidating_a_pending_fill_cancels_its_delivery() {
    let mut cache = TwoLevelCache::new(PolicyKind::Lru, &[4], 8);
    let key = key_of(0, 7);
    cache.fill_pending(0, key);
    assert_eq!(cache.pending_len(), 1);
    let dropped = cache.invalidate_vertices(&[7], 0);
    assert!(dropped >= 1, "pending metadata was resident");
    assert_eq!(cache.pending_len(), 0, "pending entry withdrawn");
    // Content arriving after the invalidation must not resurrect the row.
    cache.complete_fill(key, &[1.0, 2.0], 0);
    assert!(cache.get_row(0, key).is_none(), "late delivery must be dropped");
}

#[test]
fn invalidation_misses_untouched_vertices() {
    let mut cache = TwoLevelCache::new(PolicyKind::Jaca, &[8], 16);
    for v in 0..4u32 {
        cache.set_priority(0, key_of(0, v), v + 1);
        cache.fill(0, key_of(0, v), vec![v as f32], 0);
    }
    let dropped = cache.invalidate_vertices(&[1, 3], 1);
    assert!(dropped >= 2);
    assert_eq!(cache.lookup(0, key_of(0, 0)), Hit::Local, "vertex 0 untouched");
    assert_eq!(cache.lookup(0, key_of(0, 2)), Hit::Local, "vertex 2 untouched");
    assert_eq!(cache.lookup(0, key_of(0, 1)), Hit::Miss);
    assert_eq!(cache.lookup(0, key_of(0, 3)), Hit::Miss);
}

#[test]
fn resize_after_invalidation_respects_new_budgets() {
    let mut cache = TwoLevelCache::new(PolicyKind::Lru, &[8], 8);
    for v in 0..8u32 {
        cache.fill(0, key_of(0, v), vec![v as f32], 0);
    }
    cache.invalidate_vertices(&[0, 1], 0);
    assert_eq!(cache.local_len(0), 6);
    // A dynamic update shrank the halo → smaller adaptive budget.
    cache.resize(&[3], 4);
    assert!(cache.local_len(0) <= 3);
    assert!(cache.global_len() <= 4);
    assert_eq!(cache.local_capacity(0), 3);
    assert_eq!(cache.global_capacity(), 4);
    // Survivors still serve hits.
    let resident: Vec<u32> = (0..8)
        .filter(|&v| cache.resident_anywhere(0, key_of(0, v)))
        .collect();
    assert!(!resident.is_empty());
    for v in resident {
        assert_ne!(cache.lookup(0, key_of(0, v)), Hit::Miss);
    }
}

#[test]
fn identity_permutation_is_bitwise_noop() {
    let mut rng = Rng::new(23);
    let (g, _) = sbm(200, 3, 7.0, 2.0, &mut rng);
    let id: Vec<u32> = (0..g.n() as u32).collect();
    assert_eq!(apply(&g, &id), g);
}

#[test]
fn reorders_are_deterministic_permutations() {
    for seed in [31u64, 37, 41] {
        let mut rng = Rng::new(seed);
        let (g, _) = skewed_sbm(250, 4, 8.0, 2.0, 1.8, &mut rng);
        for perm in [bfs_order(&g), degree_order(&g)] {
            let mut seen = vec![false; g.n()];
            for &x in &perm {
                assert!(!seen[x as usize], "seed {seed}: not a permutation");
                seen[x as usize] = true;
            }
        }
        // Same input, same output — no hidden randomness.
        assert_eq!(bfs_order(&g), bfs_order(&g));
        assert_eq!(degree_order(&g), degree_order(&g));
    }
}

#[test]
fn degree_order_places_hubs_first_with_stable_ties() {
    let mut rng = Rng::new(43);
    let (g, _) = sbm(180, 3, 6.0, 2.0, &mut rng);
    let perm = degree_order(&g);
    // New position order must be degree-descending, ties by old id.
    let mut by_new: Vec<u32> = vec![0; g.n()];
    for (old, &new) in perm.iter().enumerate() {
        by_new[new as usize] = old as u32;
    }
    for w in by_new.windows(2) {
        let (da, db) = (g.degree(w[0]), g.degree(w[1]));
        assert!(
            da > db || (da == db && w[0] < w[1]),
            "positions must sort by (degree desc, old id asc)"
        );
    }
}

#[test]
fn reorder_composes_with_dynamic_deletions() {
    // Reordering after updates equals reordering the rebuilt graph:
    // `apply` consumes only the CSR, so the two pipelines converge.
    use capgnn::graph::delta::{DeltaGraph, Update};
    let mut rng = Rng::new(47);
    let (g, _) = sbm(120, 3, 6.0, 2.0, &mut rng);
    let mut dg = DeltaGraph::new(g.clone());
    let batch: Vec<Update> = (0..40)
        .map(|_| {
            let u = rng.index(g.n()) as u32;
            let v = rng.index(g.n()) as u32;
            if rng.index(2) == 0 {
                Update::Insert(u, v)
            } else {
                Update::Delete(u, v)
            }
        })
        .collect();
    dg.apply(&batch).unwrap();
    let snap = dg.snapshot();
    let perm = bfs_order(&snap);
    let h = apply(&snap, &perm);
    h.check_invariants().unwrap();
    assert_eq!(h.m(), snap.m());
    // Locality metric is finite and computed over the same edge count.
    assert!(locality_cost(&h).is_finite());
}

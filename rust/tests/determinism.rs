//! Bit-identity contract of the threaded epoch executor: for the same
//! seed/config, `ExecMode::Threaded` must produce exactly the same
//! `EpochStats`/`TrainReport` numbers as the sequential reference —
//! losses, accuracies, simulated times, byte accounting and cache
//! counters — across worker counts, caching on/off and quantization
//! on/off. This is what makes the threaded path a drop-in replacement.
//! The same file carries the cross-strategy contract: `--strategy 1.5d`
//! must reproduce the halo reference's losses/accuracies bit-for-bit.

use capgnn::device::profile::DeviceKind;
use capgnn::dist::Cluster;
use capgnn::graph::datasets::tiny;
use capgnn::runtime::NativeBackend;
use capgnn::train::{
    ConvergenceLog, EarlyStopping, ExecMode, SampledSession, Session, StrategyKind, TrainConfig,
    TrainMode, TrainReport,
};

fn tiny_cfg(epochs: usize) -> TrainConfig {
    TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(epochs) }
}

fn sampled_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        mode: TrainMode::Sampled,
        batch_size: 32,
        fanout: vec![4, 3],
        ..tiny_cfg(epochs)
    }
}

fn run_sampled(cfg: &TrainConfig, workers: usize, exec: ExecMode) -> TrainReport {
    let ds = tiny(11);
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, workers, 7);
    let mut backend = NativeBackend::new();
    let mut cfg = cfg.clone();
    cfg.exec = exec;
    let mut session = SampledSession::build(&ds, &cluster, &mut backend, &cfg).unwrap();
    session.run_epochs(cfg.epochs).unwrap();
    session.finish().unwrap().0
}

fn run_on(cfg: &TrainConfig, cluster: &Cluster, exec: ExecMode) -> TrainReport {
    let ds = tiny(11);
    let mut backend = NativeBackend::new();
    let mut cfg = cfg.clone();
    cfg.exec = exec;
    let mut session = Session::build(&ds, cluster, &mut backend, &cfg).unwrap();
    session.run_epochs(cfg.epochs).unwrap();
    session.finish().unwrap().0
}

fn run(cfg: &TrainConfig, workers: usize, exec: ExecMode) -> TrainReport {
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, workers, 7);
    run_on(cfg, &cluster, exec)
}

fn assert_identical(a: &TrainReport, b: &TrainReport, what: &str) {
    assert_eq!(a.losses, b.losses, "{what}: losses");
    assert_eq!(a.val_accs, b.val_accs, "{what}: val accs");
    assert_eq!(a.test_acc, b.test_acc, "{what}: test acc");
    assert_eq!(a.epoch_times, b.epoch_times, "{what}: simulated epoch times");
    assert_eq!(a.comm_times, b.comm_times, "{what}: simulated comm times");
    assert_eq!(a.bytes_moved, b.bytes_moved, "{what}: bytes moved");
    assert_eq!(a.bytes_saved, b.bytes_saved, "{what}: bytes saved");
    assert_eq!(a.cross_bytes_moved, b.cross_bytes_moved, "{what}: cross-machine bytes");
    assert_eq!(a.cross_bytes_naive, b.cross_bytes_naive, "{what}: naive cross bytes");
    assert_eq!(a.broadcast_bytes, b.broadcast_bytes, "{what}: broadcast bytes");
    assert_eq!(a.strategy, b.strategy, "{what}: strategy label");
    assert_eq!(a.cache, b.cache, "{what}: cache counters");
}

/// Numerics-only comparison for cross-strategy checks: losses,
/// accuracies, and the convergence trajectory must agree bitwise, while
/// byte/time accounting legitimately differs (per-row halo charges vs
/// whole-block broadcast charges).
fn assert_same_numerics(a: &TrainReport, b: &TrainReport, what: &str) {
    assert_eq!(a.losses, b.losses, "{what}: losses");
    assert_eq!(a.val_accs, b.val_accs, "{what}: val accs");
    assert_eq!(
        a.test_acc.to_bits(),
        b.test_acc.to_bits(),
        "{what}: test acc ({} vs {})",
        a.test_acc,
        b.test_acc
    );
}

/// The PR8 tentpole contract: `--strategy 1.5d` produces bit-identical
/// losses/accuracies to `--strategy halo` across 1/2/4 workers ×
/// Sequential/Threaded × cache on/off × replication 1/2 — and within the
/// 1.5D strategy, Threaded ≡ Sequential down to the byte accounting.
#[test]
fn one_half_d_matches_halo_bitwise() {
    for &workers in &[1usize, 2, 4] {
        for &use_cache in &[true, false] {
            for &replication in &[1usize, 2] {
                let mut halo_cfg = tiny_cfg(3);
                halo_cfg.use_cache = use_cache;
                let mut od_cfg = halo_cfg.clone();
                od_cfg.strategy = StrategyKind::OneHalfD;
                od_cfg.replication = replication;
                let what =
                    format!("workers={workers} cache={use_cache} replication={replication}");
                let halo = run(&halo_cfg, workers, ExecMode::Sequential);
                let od_seq = run(&od_cfg, workers, ExecMode::Sequential);
                let od_thr = run(&od_cfg, workers, ExecMode::Threaded);
                assert_same_numerics(&halo, &od_seq, &format!("{what}: halo vs 1.5d"));
                // Same strategy, different executor: everything matches,
                // including the broadcast-byte accounting.
                assert_identical(&od_seq, &od_thr, &format!("{what}: 1.5d seq vs thr"));
                // Report labeling and per-strategy byte semantics.
                assert_eq!(halo.strategy, "halo", "{what}");
                assert_eq!(od_seq.strategy, "1.5d", "{what}");
                assert_eq!(halo.broadcast_bytes, 0, "{what}: halo broadcasts nothing");
                if workers > 1 {
                    assert!(
                        od_seq.broadcast_bytes > 0,
                        "{what}: 1.5d moved no blocks across {workers} workers?"
                    );
                }
                assert!(
                    od_seq.broadcast_bytes <= od_seq.bytes_moved,
                    "{what}: broadcast bytes are a subset of bytes moved"
                );
                assert!(halo.losses.iter().all(|l| l.is_finite()), "{what}");
            }
        }
    }
}

/// Strategies also agree bitwise across machine boundaries: on the 2M-2D
/// preset the 1.5D block frames cross the interconnect yet deliver the
/// same rows, so the convergence trajectory is unchanged.
#[test]
fn one_half_d_matches_halo_multi_machine() {
    let cluster = Cluster::preset("2M-2D").unwrap();
    for &replication in &[1usize, 2] {
        let halo_cfg = tiny_cfg(3);
        let mut od_cfg = halo_cfg.clone();
        od_cfg.strategy = StrategyKind::OneHalfD;
        od_cfg.replication = replication;
        let what = format!("2M-2D replication={replication}");
        let halo = run_on(&halo_cfg, &cluster, ExecMode::Sequential);
        let od_seq = run_on(&od_cfg, &cluster, ExecMode::Sequential);
        let od_thr = run_on(&od_cfg, &cluster, ExecMode::Threaded);
        assert_same_numerics(&halo, &od_seq, &format!("{what}: halo vs 1.5d"));
        assert_identical(&od_seq, &od_thr, &format!("{what}: 1.5d seq vs thr"));
        // Whole blocks crossed the machine boundary as real frames.
        assert!(od_seq.cross_bytes_moved > 0, "{what}: no cross-machine blocks?");
        assert!(od_seq.broadcast_bytes > 0, "{what}: no broadcasts?");
    }
}

/// The satellite contract: 1/2/4 workers × 3 epochs × cache on/off ×
/// quantization on/off, threaded ≡ sequential bit-for-bit.
#[test]
fn threaded_matches_sequential_bitwise() {
    for &workers in &[1usize, 2, 4] {
        for &(use_cache, bits) in &[
            (true, None),
            (false, None),
            (true, Some(8u8)),
            (false, Some(8u8)),
        ] {
            let mut cfg = tiny_cfg(3);
            cfg.use_cache = use_cache;
            cfg.quantize_bits = bits;
            if bits.is_some() {
                // tiny's f_dim is 16 → int8 row + scales.
                cfg.quantized_row_bytes = Some(16 + 8);
            }
            let what = format!("workers={workers} cache={use_cache} bits={bits:?}");
            let seq = run(&cfg, workers, ExecMode::Sequential);
            let thr = run(&cfg, workers, ExecMode::Threaded);
            assert_identical(&seq, &thr, &what);
            // Sanity: training actually happened.
            assert_eq!(seq.losses.len(), 3, "{what}");
            assert!(seq.losses.iter().all(|l| l.is_finite()), "{what}");
        }
    }
}

/// The multi-machine contract (§7): on the 2M-2D and 2M-4D presets the
/// threaded executor — per-worker threads plus one router thread per
/// machine, with halo rows crossing machines as serialized frames — is
/// bit-identical to the sequential reference, across caching on/off and
/// AdaQP on/off. Cross-machine wire bytes (measured from the frames) and
/// the hierarchical all-reduce accounting must agree exactly too.
#[test]
fn multi_machine_threaded_matches_sequential() {
    for preset in ["2M-2D", "2M-4D"] {
        let cluster = Cluster::preset(preset).unwrap();
        for &(use_cache, bits) in
            &[(true, None), (false, None), (true, Some(8u8)), (false, Some(8u8))]
        {
            let mut cfg = tiny_cfg(3);
            cfg.use_cache = use_cache;
            cfg.quantize_bits = bits;
            if bits.is_some() {
                cfg.quantized_row_bytes = Some(16 + 8);
            }
            let what = format!("{preset} cache={use_cache} bits={bits:?}");
            let seq = run_on(&cfg, &cluster, ExecMode::Sequential);
            let thr = run_on(&cfg, &cluster, ExecMode::Threaded);
            assert_identical(&seq, &thr, &what);
            assert_eq!(seq.losses.len(), 3, "{what}");
            assert!(seq.losses.iter().all(|l| l.is_finite()), "{what}");
            // Frames actually crossed machines, and the §7 dedup +
            // hierarchical reduce beat the naive wire strictly.
            assert!(seq.cross_bytes_moved > 0, "{what}: no cross traffic?");
            assert!(
                seq.cross_bytes_moved < seq.cross_bytes_naive,
                "{what}: dedup must reduce cross bytes ({} vs {})",
                seq.cross_bytes_moved,
                seq.cross_bytes_naive
            );
        }
    }
}

/// Skip-exchange (historical halo reuse) and bounded-staleness refresh
/// epochs exercise every delivery path; GraphSAGE exercises the two-matrix
/// backward. All must stay bit-identical.
#[test]
fn threaded_matches_sequential_with_staleness_and_sage() {
    let mut cfg = tiny_cfg(5);
    cfg.skip_exchange = true;
    cfg.refresh_interval = 2;
    let seq = run(&cfg, 3, ExecMode::Sequential);
    let thr = run(&cfg, 3, ExecMode::Threaded);
    assert_identical(&seq, &thr, "skip_exchange + refresh");

    let mut cfg = tiny_cfg(3);
    cfg.model = capgnn::model::ModelKind::Sage;
    let seq = run(&cfg, 2, ExecMode::Sequential);
    let thr = run(&cfg, 2, ExecMode::Threaded);
    assert_identical(&seq, &thr, "sage");
}

/// Observers (early stopping, convergence logs) see identical per-epoch
/// stats from the threaded executor, so they stop at the same epoch.
#[test]
fn observers_see_identical_stats_on_threads() {
    let ds = tiny(5);
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let run_logged = |exec: ExecMode| {
        let mut backend = NativeBackend::new();
        let mut cfg = tiny_cfg(6);
        cfg.exec = exec;
        let mut session = Session::build(&ds, &cluster, &mut backend, &cfg).unwrap();
        let mut log = ConvergenceLog::default();
        session.run(6, &mut log).unwrap();
        log.history
            .iter()
            .map(|e| (e.loss, e.val_acc, e.bytes_moved))
            .collect::<Vec<_>>()
    };
    assert_eq!(run_logged(ExecMode::Sequential), run_logged(ExecMode::Threaded));

    // Early stopping halts at the same epoch in both modes.
    let stopped_at = |exec: ExecMode| {
        let mut backend = NativeBackend::new();
        let mut cfg = tiny_cfg(50);
        cfg.exec = exec;
        let mut session = Session::build(&ds, &cluster, &mut backend, &cfg).unwrap();
        let mut stop = EarlyStopping::new(2, f32::INFINITY);
        let ran = session.run(50, &mut stop).unwrap();
        (ran, stop.stopped_at)
    };
    assert_eq!(stopped_at(ExecMode::Sequential), stopped_at(ExecMode::Threaded));
}

/// Sampled mode, same contract as full-batch: the threaded executor (a
/// sampler pipeline feeding the batch loop) is bit-identical to the
/// sequential reference across worker counts × cache on/off × AdaQP
/// on/off — including simulated times, byte accounting and cache
/// counters.
#[test]
fn sampled_threaded_matches_sequential_bitwise() {
    for &workers in &[1usize, 2, 4] {
        for &(use_cache, bits) in &[(true, None), (false, None), (true, Some(8u8))] {
            let mut cfg = sampled_cfg(3);
            cfg.use_cache = use_cache;
            cfg.quantize_bits = bits;
            if bits.is_some() {
                cfg.quantized_row_bytes = Some(16 + 8);
            }
            let what = format!("sampled workers={workers} cache={use_cache} bits={bits:?}");
            let seq = run_sampled(&cfg, workers, ExecMode::Sequential);
            let thr = run_sampled(&cfg, workers, ExecMode::Threaded);
            assert_identical(&seq, &thr, &what);
            assert_eq!(seq.losses.len(), 3, "{what}");
            assert!(seq.losses.iter().all(|l| l.is_finite()), "{what}");
        }
    }
}

/// The sampled trainer's headline guarantee: a batch is processed whole
/// by one worker, so the *numerics* — losses, accuracies — are
/// bit-identical across 1/2/4 workers at a fixed seed. (Accounting
/// fields like bytes and simulated times legitimately differ with the
/// partition shape.) Holds with and without AdaQP quantization, because
/// wire rows are quantized with a vertex-keyed RNG.
#[test]
fn sampled_losses_invariant_across_worker_counts() {
    for &bits in &[None, Some(8u8)] {
        let mut cfg = sampled_cfg(3);
        cfg.quantize_bits = bits;
        if bits.is_some() {
            cfg.quantized_row_bytes = Some(16 + 8);
        }
        let what = format!("sampled bits={bits:?}");
        let p1 = run_sampled(&cfg, 1, ExecMode::Sequential);
        let p2 = run_sampled(&cfg, 2, ExecMode::Sequential);
        let p4 = run_sampled(&cfg, 4, ExecMode::Threaded);
        assert_eq!(p1.losses, p2.losses, "{what}: losses p1 vs p2");
        assert_eq!(p1.losses, p4.losses, "{what}: losses p1 vs p4");
        assert_eq!(p1.val_accs, p2.val_accs, "{what}: val accs p1 vs p2");
        assert_eq!(p1.val_accs, p4.val_accs, "{what}: val accs p1 vs p4");
        assert_eq!(p1.test_acc, p2.test_acc, "{what}: test acc p1 vs p2");
        assert_eq!(p1.test_acc, p4.test_acc, "{what}: test acc p1 vs p4");
        assert!(p1.losses.iter().all(|l| l.is_finite()), "{what}");
    }
}

/// The measured wall-clock side-channel is populated in both modes.
#[test]
fn measured_wall_clock_is_recorded() {
    let cfg = tiny_cfg(2);
    for exec in [ExecMode::Sequential, ExecMode::Threaded] {
        let r = run(&cfg, 2, exec);
        assert_eq!(r.epoch_wall.len(), 2, "{exec:?}");
        assert!(r.total_wall() > 0.0, "{exec:?}");
        assert!(r.wall_stages.execute > 0.0, "{exec:?}");
        // Measured and simulated clocks are independent quantities.
        assert!(r.epoch_wall.iter().all(|&w| w > 0.0), "{exec:?}");
    }
}

//! Ingestion tests: edge-list parsing and parallel CSR assembly edge
//! cases (typed errors, never panics), `.cgr` round-trip bit-exactness,
//! and the PR 5 acceptance path — training on an ingested on-disk graph
//! is bit-identical to training on the equivalent in-memory graph.

use capgnn::device::profile::DeviceKind;
use capgnn::dist::Cluster;
use capgnn::graph::datasets::{load_file_dataset, synthetic_node_data, DatasetSource};
use capgnn::graph::io::{
    build_csr, load_cgr, load_cgr_bytes, read_edge_list, save_cgr, write_edge_list, IoError,
};
use capgnn::graph::{Graph, NodeData};
use capgnn::runtime::NativeBackend;
use capgnn::train::{Session, TrainConfig};
use capgnn::util::Rng;
use std::path::PathBuf;

/// Unique temp path per test (the suite may run tests concurrently).
fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("capgnn-ingest-{}-{tag}", std::process::id()))
}

fn rand_edges(rng: &mut Rng, n: usize, m: usize) -> Vec<(u32, u32)> {
    (0..m).map(|_| (rng.index(n) as u32, rng.index(n) as u32)).collect()
}

// ---------------------------------------------------------------- errors

#[test]
fn empty_file_is_a_typed_error() {
    assert!(matches!(read_edge_list("".as_bytes(), None), Err(IoError::Empty)));
    // Comments and blank lines only: still no edges.
    assert!(matches!(
        read_edge_list("# nothing\n\n% here\n".as_bytes(), None),
        Err(IoError::Empty)
    ));
    // But an empty list with a declared vertex count is a valid
    // all-isolated graph.
    let list = read_edge_list("".as_bytes(), Some(5)).unwrap();
    let (g, st) = build_csr(list.n, &list.edges, 2).unwrap();
    assert_eq!(g.n(), 5);
    assert_eq!(g.m(), 0);
    assert_eq!(st.isolated, 5);
}

#[test]
fn out_of_range_ids_are_typed_errors() {
    // At parse time, with the offending line.
    let err = read_edge_list("0 1\n1 7\n".as_bytes(), Some(4)).unwrap_err();
    match err {
        IoError::VertexOutOfRange { vertex, n, line } => {
            assert_eq!(vertex, 7);
            assert_eq!(n, 4);
            assert_eq!(line, Some(2));
        }
        other => panic!("expected VertexOutOfRange, got {other:?}"),
    }
    // At build time, without a line.
    let err = build_csr(3, &[(0, 1), (2, 9)], 1).unwrap_err();
    assert!(matches!(err, IoError::VertexOutOfRange { vertex: 9, n: 3, line: None }));
}

#[test]
fn truncated_and_corrupt_cgr_are_typed_errors() {
    let mut rng = Rng::new(3);
    let g = Graph::random(30, 120, &mut rng);
    let path = tmp("trunc.cgr");
    save_cgr(&path, &g, None).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // Truncate at various depths: header, offsets, indices.
    for cut in [0usize, 3, 10, 24, 40, bytes.len() - 1] {
        let err = load_cgr_bytes(&bytes[..cut]).unwrap_err();
        assert!(
            matches!(err, IoError::Truncated { .. }),
            "cut at {cut}: expected Truncated, got {err:?}"
        );
    }

    // Wrong magic.
    let mut bad = bytes.clone();
    bad[0] = b'X';
    assert!(matches!(load_cgr_bytes(&bad), Err(IoError::BadMagic { .. })));

    // Future version.
    let mut bad = bytes.clone();
    bad[4] = 0xFF;
    bad[5] = 0xFF;
    assert!(matches!(load_cgr_bytes(&bad), Err(IoError::UnsupportedVersion(0xFFFF))));

    // Unknown flag bits.
    let mut bad = bytes.clone();
    bad[6] = 0xF0;
    assert!(matches!(load_cgr_bytes(&bad), Err(IoError::Corrupt(_))));

    // Trailing garbage.
    let mut bad = bytes.clone();
    bad.extend_from_slice(&[0, 1, 2]);
    assert!(matches!(load_cgr_bytes(&bad), Err(IoError::Corrupt(_))));

    // Non-monotone offsets (offsets start at byte 24; swap two rows).
    let mut bad = bytes.clone();
    bad[24..32].copy_from_slice(&u64::MAX.to_le_bytes());
    let err = load_cgr_bytes(&bad).unwrap_err();
    assert!(matches!(err, IoError::Corrupt(_)), "got {err:?}");

    // A missing file is an Io error, not a panic.
    assert!(matches!(load_cgr(&tmp("never-written.cgr")), Err(IoError::Io(_))));
}

/// A structurally plausible file that breaks the crate-wide CSR
/// invariants (here: a one-directional edge) is rejected at load — it
/// must not flow into training.
#[test]
fn asymmetric_cgr_is_rejected() {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"CGRF");
    bytes.extend_from_slice(&1u16.to_le_bytes()); // version
    bytes.extend_from_slice(&0u16.to_le_bytes()); // flags
    bytes.extend_from_slice(&2u64.to_le_bytes()); // n
    bytes.extend_from_slice(&1u64.to_le_bytes()); // arcs
    for o in [0u64, 1, 1] {
        bytes.extend_from_slice(&o.to_le_bytes()); // offsets
    }
    bytes.extend_from_slice(&1u32.to_le_bytes()); // lone arc 0→1
    assert!(matches!(load_cgr_bytes(&bytes), Err(IoError::Corrupt(_))));
}

/// Zero-width features in the node-data section are corrupt, not a
/// degenerate-but-trainable dataset.
#[test]
fn zero_f_dim_node_data_is_rejected() {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"CGRF");
    bytes.extend_from_slice(&1u16.to_le_bytes()); // version
    bytes.extend_from_slice(&1u16.to_le_bytes()); // flags: node data
    bytes.extend_from_slice(&1u64.to_le_bytes()); // n
    bytes.extend_from_slice(&0u64.to_le_bytes()); // arcs
    for o in [0u64, 0] {
        bytes.extend_from_slice(&o.to_le_bytes()); // offsets
    }
    bytes.extend_from_slice(&0u32.to_le_bytes()); // f_dim = 0
    bytes.extend_from_slice(&1u32.to_le_bytes()); // num_classes
    bytes.extend_from_slice(&0u32.to_le_bytes()); // label of vertex 0
    bytes.push(0b001); // mask byte
    assert!(matches!(load_cgr_bytes(&bytes), Err(IoError::Corrupt(_))));
}

// ------------------------------------------------------ CSR construction

#[test]
fn duplicates_self_loops_and_isolated_vertices() {
    let text = "0 1\n1 0\n0 1\n2 2\n0 3\n";
    let list = read_edge_list(text.as_bytes(), Some(6)).unwrap();
    let (g, st) = build_csr(list.n, &list.edges, 3).unwrap();
    assert_eq!(g.n(), 6);
    assert_eq!(g.m(), 2); // {0,1} and {0,3}
    assert_eq!(st.self_loops, 1);
    assert_eq!(st.duplicates, 2);
    // 2 (self-loop only), 4 and 5 (declared, never mentioned).
    assert_eq!(st.isolated, 3);
    assert_eq!(g.degree(5), 0);
    g.check_invariants().unwrap();
}

/// The property the parallel build stands on: for any thread count the
/// CSR is bit-identical to the single-threaded order, which in turn
/// matches `Graph::from_edges`.
#[test]
fn parallel_build_matches_single_threaded() {
    let mut rng = Rng::new(77);
    for (n, m) in [(1usize, 8usize), (13, 40), (100, 450), (513, 2000)] {
        let edges = rand_edges(&mut rng, n, m);
        let (single, _) = build_csr(n, &edges, 1).unwrap();
        let want = Graph::from_edges(n, &edges);
        assert_eq!(single, want, "n={n} single-thread vs from_edges");
        for threads in [2usize, 3, 4, 8] {
            let (par, st) = build_csr(n, &edges, threads).unwrap();
            assert_eq!(par, single, "n={n} threads={threads}");
            let (_, st1) = build_csr(n, &edges, 1).unwrap();
            assert_eq!(st, st1, "stats must not depend on threads");
        }
    }
}

// ----------------------------------------------------------- round-trips

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// ingest → save → load round-trips bit-exactly, across sizes and with
/// and without a node-data section.
#[test]
fn cgr_roundtrip_is_bit_exact() {
    let mut rng = Rng::new(9);
    for (i, (n, m)) in [(1usize, 2usize), (37, 150), (256, 1500)].iter().enumerate() {
        let edges = rand_edges(&mut rng, *n, *m);
        let (g, _) = build_csr(*n, &edges, 2).unwrap();
        let data = synthetic_node_data(&g, 4, 8, 5);
        let path = tmp(&format!("rt{i}.cgr"));

        // Graph only.
        save_cgr(&path, &g, None).unwrap();
        let back = load_cgr(&path).unwrap();
        assert_eq!(back.graph, g);
        assert!(back.data.is_none());

        // Graph + node data.
        save_cgr(&path, &g, Some(&data)).unwrap();
        let back = load_cgr(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.graph, g);
        let d = back.data.expect("node data section");
        assert!(bits_eq(&d.features, &data.features), "feature bits must round-trip");
        assert_eq!(d.f_dim, data.f_dim);
        assert_eq!(d.labels, data.labels);
        assert_eq!(d.num_classes, data.num_classes);
        assert_eq!(d.train_mask, data.train_mask);
        assert_eq!(d.val_mask, data.val_mask);
        assert_eq!(d.test_mask, data.test_mask);
    }
}

/// Text edge list → `build_csr` → `.cgr` → text again is the identity on
/// the graph.
#[test]
fn edge_list_roundtrips_through_cgr() {
    let mut rng = Rng::new(21);
    let g = Graph::random(80, 300, &mut rng);
    // Dump the undirected edges (u < v once each).
    let mut edges = Vec::new();
    for u in 0..g.n() as u32 {
        for &v in g.nbrs(u) {
            if u < v {
                edges.push((u, v));
            }
        }
    }
    let mut text = Vec::new();
    write_edge_list(&mut text, &edges).unwrap();
    let list = read_edge_list(text.as_slice(), Some(g.n())).unwrap();
    let (back, st) = build_csr(list.n, &list.edges, 4).unwrap();
    assert_eq!(back, g);
    assert_eq!(st.duplicates, 0);
    assert_eq!(st.self_loops, 0);
}

// ------------------------------------------- end-to-end training parity

/// The acceptance criterion: `capgnn ingest` + `train --dataset file:…`
/// produces losses bit-identical to training on the equivalent in-memory
/// graph. This is that path at the library level: same graph, same
/// (deterministic) node data, one side routed through the `.cgr` file.
#[test]
fn file_dataset_trains_bit_identical_to_in_memory() {
    let mut rng = Rng::new(55);
    let n = 120;
    let edges = rand_edges(&mut rng, n, 600);
    let (graph, _) = build_csr(n, &edges, 2).unwrap();

    // In-memory side: the equivalent Graph + deterministic node data.
    let seed = 42u64;
    let in_mem = capgnn::graph::Dataset {
        name: "inmem",
        label: "Ty",
        graph: graph.clone(),
        data: synthetic_node_data(&graph, 4, 16, seed),
    };

    // On-disk side: graph-only .cgr; loading synthesizes the same rows.
    let path = tmp("e2e.cgr");
    save_cgr(&path, &graph, None).unwrap();
    let from_file = load_file_dataset(&path, seed).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(from_file.graph, in_mem.graph);
    assert!(bits_eq(&from_file.data.features, &in_mem.data.features));

    let cfg = TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(5) };
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let mut b1 = NativeBackend::new();
    let r_mem = Session::train(&in_mem, &cluster, &mut b1, &cfg).unwrap();
    let mut b2 = NativeBackend::new();
    let r_file = Session::train(&from_file, &cluster, &mut b2, &cfg).unwrap();

    assert_eq!(r_mem.losses, r_file.losses, "losses must be bit-identical");
    assert_eq!(r_mem.val_accs, r_file.val_accs);
    assert_eq!(r_mem.test_acc, r_file.test_acc);
    assert_eq!(r_mem.bytes_moved, r_file.bytes_moved);
}

/// A `.cgr` with an embedded node-data section trains bit-identically to
/// the in-memory dataset it was saved from (the self-contained variant).
#[test]
fn embedded_node_data_trains_bit_identical() {
    let ds = capgnn::graph::datasets::tiny(42);
    let path = tmp("tiny.cgr");
    save_cgr(&path, &ds.graph, Some(&ds.data)).unwrap();
    let from_file = load_file_dataset(&path, 999).unwrap(); // seed unused: data embedded
    std::fs::remove_file(&path).ok();

    let cfg = TrainConfig { hidden: 16, layers: 2, lr: 0.05, ..TrainConfig::capgnn(4) };
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 3);
    let mut b1 = NativeBackend::new();
    let r_a = Session::train(&ds, &cluster, &mut b1, &cfg).unwrap();
    let mut b2 = NativeBackend::new();
    let r_b = Session::train(&from_file, &cluster, &mut b2, &cfg).unwrap();
    assert_eq!(r_a.losses, r_b.losses);
    assert_eq!(r_a.val_accs, r_b.val_accs);
}

/// `--dataset file:<path>` resolves through the registry and the full
/// `config::run_spec` path.
#[test]
fn run_spec_accepts_file_sources() {
    let mut rng = Rng::new(13);
    let g = Graph::random(64, 256, &mut rng);
    let path = tmp("spec.cgr");
    save_cgr(&path, &g, None).unwrap();

    let arg = format!("file:{}", path.display());
    let source = DatasetSource::parse(&arg).unwrap();
    let ds = source.build(42, 1.0).unwrap();
    assert_eq!(ds.graph, g);
    assert_eq!(ds.label, "Fi");

    let args = capgnn::util::Args::parse(
        ["--dataset", arg.as_str(), "--parts", "2", "--epochs", "3"]
            .iter()
            .map(|s| s.to_string()),
    );
    let spec = capgnn::config::run_spec(&args).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(spec.dataset.graph, g);
    assert!(matches!(spec.source, DatasetSource::File(_)));
    assert_eq!(spec.gpus.len(), 2);
}

/// NodeData invariants survive the mask byte-packing (a vertex in no
/// split and overlapping splits both round-trip).
#[test]
fn mask_packing_handles_partial_splits() {
    let g = Graph::from_edges(3, &[(0, 1), (1, 2)]);
    let data = NodeData {
        features: vec![0.5; 3 * 2],
        f_dim: 2,
        labels: vec![0, 1, 0],
        num_classes: 2,
        train_mask: vec![true, false, false],
        val_mask: vec![false, false, false],
        test_mask: vec![false, false, true], // vertex 1 is in no split
    };
    let path = tmp("masks.cgr");
    save_cgr(&path, &g, Some(&data)).unwrap();
    let back = load_cgr(&path).unwrap().data.unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back.train_mask, data.train_mask);
    assert_eq!(back.val_mask, data.val_mask);
    assert_eq!(back.test_mask, data.test_mask);
}

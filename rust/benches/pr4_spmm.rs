//! PR4 bench / CI perf gate: CSR SpMM aggregation vs the seed dense path.
//!
//! For three graph sizes, runs one GCN "epoch analog" (forward + backward
//! through the layer kernels) on both backends over the *same* operator:
//! - sparse: the production `NativeBackend` (CSR SpMM, scratch arena);
//! - dense:  the seed loops kept verbatim in `dense_oracle`, over the
//!   materialized n_pad×n_pad Â.
//!
//! Writes `BENCH_PR4.json` (epoch times, speedups, adjacency bytes) to
//! the repo root, then exits nonzero if at the largest size either
//! - the sparse path is not ≥5× faster than the dense path, or
//! - the sparse operator does not fit the O(n + nnz) memory bound, or
//! - sparse and dense outputs disagree on a single bit.
//!
//! `BENCH_QUICK=1` shrinks the sizes for smoke runs (the 5× gate is
//! skipped there: at toy sizes the O(n²) dense scan has not yet pulled
//! away from the shared O(n·d²) transform cost).

use capgnn::graph::{Graph, SparseAdj};
use capgnn::runtime::native::dense_oracle;
use capgnn::runtime::{Backend, NativeBackend};
use capgnn::util::bench;
use capgnn::util::bench_json::BenchDoc;
use capgnn::util::json::{arr, num, obj, Json};
use capgnn::util::Rng;

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn main() {
    let quick = bench::quick_mode();
    // (vertices, sampled edges): avg degree ≈ 8 at every size.
    let sizes: &[(usize, usize)] = if quick {
        &[(512, 2048), (1024, 4096), (2048, 8192)]
    } else {
        &[(2048, 8192), (8192, 32768), (16384, 65536)]
    };
    let (d_in, d_out) = (32usize, 32usize);
    let reps = if quick { 2 } else { 3 };

    let mut entries: Vec<Json> = Vec::new();
    let mut last_speedup = 0.0f64;
    let mut last_sparse_bytes = 0usize;
    let mut last_dense_bytes = 0usize;
    let mut last_shape = (0usize, 0usize); // (n_pad, nnz)
    for &(n, m) in sizes {
        let mut rng = Rng::new(7);
        let g = Graph::random(n, m, &mut rng);
        let n_pad = n.next_power_of_two();
        let adj = SparseAdj::gcn_normalized(&g, n_pad);
        let h: Vec<f32> = (0..n_pad * d_in).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() as f32).collect();
        let dgrad: Vec<f32> = (0..n_pad * d_out).map(|_| rng.normal() as f32).collect();

        // Sparse epoch analog (1 aggregation thread — the per-worker
        // serial hot loop).
        let mut be = NativeBackend::new();
        let mut out = Vec::new();
        let (mut g_w, mut d_h) = (Vec::new(), Vec::new());
        let sparse = bench::measure(
            || {
                be.gcn_fwd(n_pad, d_in, d_out, true, &adj, &h, &w, &mut out).unwrap();
                be.gcn_bwd(n_pad, d_in, d_out, true, &adj, &h, &w, &dgrad, &mut g_w,
                           &mut d_h)
                    .unwrap();
                std::hint::black_box((&out, &d_h));
            },
            1,
            reps,
        );
        // 4 aggregation threads (reported, not gated — the gate must not
        // depend on CI core counts).
        let mut be4 = NativeBackend::with_threads(4);
        let mut out4 = Vec::new();
        let (mut g_w4, mut d_h4) = (Vec::new(), Vec::new());
        let sparse4 = bench::measure(
            || {
                be4.gcn_fwd(n_pad, d_in, d_out, true, &adj, &h, &w, &mut out4).unwrap();
                be4.gcn_bwd(n_pad, d_in, d_out, true, &adj, &h, &w, &dgrad, &mut g_w4,
                            &mut d_h4)
                    .unwrap();
                std::hint::black_box((&out4, &d_h4));
            },
            1,
            reps,
        );

        // Dense epoch analog: the seed path over the materialized Â.
        let a = adj.to_dense();
        let mut dense_out = Vec::new();
        let mut dense_dh = Vec::new();
        let dense = bench::measure(
            || {
                dense_out = dense_oracle::gcn_fwd(n_pad, d_in, d_out, true, &a, &h, &w);
                let (gw, dh) =
                    dense_oracle::gcn_bwd(n_pad, d_in, d_out, true, &a, &h, &w, &dgrad);
                std::hint::black_box(&gw);
                dense_dh = dh;
            },
            1,
            reps,
        );
        if !bits_eq(&out, &dense_out) || !bits_eq(&d_h, &dense_dh)
            || !bits_eq(&out, &out4) || !bits_eq(&d_h, &d_h4)
        {
            eprintln!("PARITY BREACH at n={n}: sparse and dense outputs differ");
            std::process::exit(1);
        }

        let dense_bytes = n_pad * n_pad * 4;
        let sparse_bytes = adj.mem_bytes(); // fwd + transpose (built by bwd)
        let speedup = dense.mean / sparse.mean.max(1e-12);
        println!(
            "n={n} (pad {n_pad}, nnz {}): dense {:.4}s, sparse {:.4}s (t4 {:.4}s) — {:.1}x; \
             adjacency {dense_bytes} B dense vs {sparse_bytes} B sparse",
            adj.nnz(),
            dense.mean,
            sparse.mean,
            sparse4.mean,
            speedup
        );
        entries.push(obj(vec![
            ("n", num(n as f64)),
            ("n_pad", num(n_pad as f64)),
            ("nnz", num(adj.nnz() as f64)),
            ("dense_epoch_s", num(dense.mean)),
            ("sparse_epoch_s", num(sparse.mean)),
            ("sparse_epoch_s_t4", num(sparse4.mean)),
            ("speedup", num(speedup)),
            ("dense_adj_bytes", num(dense_bytes as f64)),
            ("sparse_adj_bytes", num(sparse_bytes as f64)),
        ]));
        last_speedup = speedup;
        last_sparse_bytes = sparse_bytes;
        last_dense_bytes = dense_bytes;
        last_shape = (n_pad, adj.nnz());
    }

    let mut doc = BenchDoc::new("pr4_spmm", "BENCH_PR4.json");
    doc.field("d_in", num(d_in as f64));
    doc.field("d_out", num(d_out as f64));
    doc.field("results", arr(entries));
    doc.field("speedup_at_largest", num(last_speedup));
    doc.field(
        "mem_ratio_at_largest",
        num(last_dense_bytes as f64 / last_sparse_bytes.max(1) as f64),
    );
    println!(
        "largest size: {last_speedup:.1}x speedup, {}x less adjacency memory",
        last_dense_bytes / last_sparse_bytes.max(1)
    );

    // O(n + nnz) memory gate: both CSR halves are ≤ 8 B per row pointer
    // + 8 B per stored entry; allow slack for allocator rounding.
    let (n_pad, nnz) = last_shape;
    let linear_bound = 16 * (n_pad + 1) + 24 * nnz;
    doc.gate(
        "adjacency_memory_linear",
        last_sparse_bytes <= linear_bound,
        &format!(
            "MEM GATE FAILED: sparse adjacency {last_sparse_bytes} B exceeds the \
             O(n + nnz) bound {linear_bound} B"
        ),
    );
    if quick {
        println!("quick mode: 5x speedup gate skipped (toy sizes)");
    } else {
        doc.gate(
            "sparse_5x_faster",
            last_speedup >= 5.0,
            &format!(
                "PERF GATE FAILED: sparse aggregation is only {last_speedup:.2}x faster than \
                 the dense path at the largest size (need >= 5x)"
            ),
        );
    }
    doc.finish();
}

//! PR8 bench / CI gate: halo exchange vs the CAGNET-style 1.5D block
//! strategy (`--strategy 1.5d`) on the multi-machine cluster presets.
//!
//! For three graph sizes × the 2M-2D and 2M-4D presets it trains the
//! same configuration under both strategies (vanilla communication —
//! cache off — so the raw communication patterns are compared on every
//! epoch, not a cold-start artifact) and records per-strategy epoch
//! time, device bytes, and cross-machine wire bytes. The crossover
//! story: halo traffic scales with the edge cut, 1.5D traffic with the
//! replication factor, so on a dense graph whole-block broadcasts
//! undercut naive per-row delivery.
//!
//! Writes `BENCH_PR8.json` to the repo root, then exits nonzero if
//! - the two strategies disagree on any loss/accuracy bit anywhere
//!   (including a Threaded 1.5D run at the smallest size), or
//! - at the densest size of either preset, 1.5D cross-machine bytes do
//!   not beat the halo-naive (no-dedup) bytes.
//!
//! `BENCH_QUICK=1` shrinks the sizes for smoke runs.

use capgnn::dist::{train_distributed, Cluster, DistReport};
use capgnn::graph::datasets::synthetic_node_data;
use capgnn::graph::{Dataset, Graph};
use capgnn::runtime::NativeBackend;
use capgnn::train::{ExecMode, StrategyKind, TrainConfig};
use capgnn::util::bench;
use capgnn::util::bench_json::BenchDoc;
use capgnn::util::json::{arr, num, obj, s, Json};
use capgnn::util::Rng;

/// Random graph (avg degree ≈ 8) with synthetic labeled features.
fn make_dataset(n: usize, seed: u64) -> Dataset {
    let m = n * 8;
    let mut rng = Rng::new(seed);
    let edges: Vec<(u32, u32)> =
        (0..m).map(|_| (rng.index(n) as u32, rng.index(n) as u32)).collect();
    let graph = Graph::from_edges(n, &edges);
    let data = synthetic_node_data(&graph, 8, 32, seed);
    Dataset { name: "bench", label: "Bn", graph, data }
}

fn run_strategy(
    ds: &Dataset,
    cluster: &Cluster,
    epochs: usize,
    strategy: StrategyKind,
    exec: ExecMode,
) -> DistReport {
    // Vanilla communication: cache off keeps cross-machine traffic on
    // every epoch, so the strategies' steady-state volumes are compared.
    let mut cfg = TrainConfig::vanilla(epochs);
    cfg.hidden = 32;
    cfg.layers = 2;
    cfg.lr = 0.05;
    cfg.exec = exec;
    cfg.strategy = strategy;
    if strategy == StrategyKind::OneHalfD {
        cfg.replication = 2;
    }
    let mut backend = NativeBackend::new();
    train_distributed(ds, cluster, &mut backend, &cfg).expect("distributed run")
}

fn main() {
    let quick = bench::quick_mode();
    let sizes: &[usize] = if quick { &[512, 1024, 2048] } else { &[2048, 4096, 8192] };
    let epochs = if quick { 2 } else { 3 };

    let mut entries: Vec<Json> = Vec::new();
    let mut bitwise_ok = true;
    let mut crossover_ok = true;
    for preset in ["2M-2D", "2M-4D"] {
        let cluster = Cluster::preset(preset).unwrap();
        for &n in sizes {
            let ds = make_dataset(n, 42);
            let halo = run_strategy(&ds, &cluster, epochs, StrategyKind::Halo,
                                    ExecMode::Sequential);
            let od = run_strategy(&ds, &cluster, epochs, StrategyKind::OneHalfD,
                                  ExecMode::Sequential);
            if halo.report.losses != od.report.losses
                || halo.report.val_accs != od.report.val_accs
                || halo.report.test_acc.to_bits() != od.report.test_acc.to_bits()
            {
                eprintln!(
                    "NUMERICS DIVERGED on {preset} n={n}: halo losses {:?} vs 1.5d {:?}",
                    halo.report.losses, od.report.losses
                );
                bitwise_ok = false;
            }
            // The threaded executor must run the block path bit-identically
            // too; one size per preset keeps the bench fast.
            if n == sizes[0] {
                let odt = run_strategy(&ds, &cluster, epochs, StrategyKind::OneHalfD,
                                       ExecMode::Threaded);
                if odt.report.losses != halo.report.losses {
                    eprintln!("NUMERICS DIVERGED on {preset} n={n}: threaded 1.5d differs");
                    bitwise_ok = false;
                }
            }
            let densest = n == *sizes.last().unwrap();
            if densest && od.cross_machine_bytes >= halo.cross_machine_bytes_naive {
                eprintln!(
                    "CROSSOVER GATE FAILED on {preset} n={n}: 1.5d cross bytes {} do not \
                     beat halo-naive {}",
                    od.cross_machine_bytes, halo.cross_machine_bytes_naive
                );
                crossover_ok = false;
            }
            println!(
                "{preset} n={n}: halo epoch {:.4}s sim / cross {} B (naive {} B) vs \
                 1.5d epoch {:.4}s sim / cross {} B ({} B broadcast)",
                halo.report.mean_epoch(),
                halo.cross_machine_bytes,
                halo.cross_machine_bytes_naive,
                od.report.mean_epoch(),
                od.cross_machine_bytes,
                od.report.broadcast_bytes,
            );
            entries.push(obj(vec![
                ("preset", s(preset)),
                ("n", num(n as f64)),
                ("workers", num(halo.workers as f64)),
                ("machines", num(halo.machines as f64)),
                ("epochs", num(epochs as f64)),
                ("replication", num(2.0)),
                ("halo_epoch_s", num(halo.report.mean_epoch())),
                ("one_half_d_epoch_s", num(od.report.mean_epoch())),
                ("halo_bytes_moved", num(halo.report.bytes_moved as f64)),
                ("one_half_d_bytes_moved", num(od.report.bytes_moved as f64)),
                ("one_half_d_broadcast_bytes", num(od.report.broadcast_bytes as f64)),
                ("halo_cross_bytes", num(halo.cross_machine_bytes as f64)),
                ("halo_cross_bytes_naive", num(halo.cross_machine_bytes_naive as f64)),
                ("one_half_d_cross_bytes", num(od.cross_machine_bytes as f64)),
            ]));
        }
    }

    let mut doc = BenchDoc::new("pr8_strategy", "BENCH_PR8.json");
    doc.field("results", arr(entries));
    doc.gate(
        "losses_bitwise_equal",
        bitwise_ok,
        "STRATEGY GATE FAILED: 1.5d diverged from halo in a loss/accuracy bit",
    );
    doc.gate(
        "one_half_d_beats_naive_at_densest",
        crossover_ok,
        "CROSSOVER GATE FAILED: 1.5d cross-machine bytes did not beat halo-naive \
         bytes at the densest size",
    );
    doc.finish();
}

//! PR7 bench / CI gate: online serving latency and throughput.
//!
//! Trains a small model through the unified `train::run` facade, then
//! replays a Zipfian request stream (`s = 1.1` over the degree-hottest
//! vertices) against a live server at 2 offered request rates × 2
//! micro-batch ceilings, recording p50/p99 queue-to-response latency,
//! sustained QPS, and the cross-request cache hit rate.
//!
//! Writes `BENCH_PR7.json` to the repo root, then exits nonzero if
//! - any configuration sees zero cross-request cache hits (the warmed
//!   JACA cache must absorb part of a Zipfian mix), or
//! - any configuration's p99 latency exceeds 500 ms, or
//! - any response set is internally inconsistent (two responses for the
//!   same vertex differ in a bit), or
//! - two fresh same-seed runs of the first configuration produce
//!   different output digests (serving determinism across processes'
//!   worth of state: new server, new cache, new workers).
//!
//! `BENCH_QUICK=1` shrinks the graph and stream for smoke runs.

use capgnn::device::profile::DeviceKind;
use capgnn::dist::Cluster;
use capgnn::graph::datasets::synthetic_node_data;
use capgnn::graph::{Dataset, Graph};
use capgnn::model::TrainedModel;
use capgnn::runtime::NativeBackend;
use capgnn::sample::Fanout;
use capgnn::serve::{
    run_driver, zipf_workload, DriverReport, Pacing, ServeConfig, Server, WorkloadConfig,
};
use capgnn::train::{run, TrainConfig};
use capgnn::util::bench;
use capgnn::util::bench_json::BenchDoc;
use capgnn::util::json::{arr, num, obj, Json};
use capgnn::util::Rng;

/// Random graph (avg degree ≈ 8) with synthetic labeled features.
fn make_dataset(n: usize, seed: u64) -> Dataset {
    let m = n * 8;
    let mut rng = Rng::new(seed);
    let edges: Vec<(u32, u32)> =
        (0..m).map(|_| (rng.index(n) as u32, rng.index(n) as u32)).collect();
    let graph = Graph::from_edges(n, &edges);
    let data = synthetic_node_data(&graph, 8, 32, seed);
    Dataset { name: "bench", label: "Bn", graph, data }
}

fn train_model(ds: &Dataset) -> TrainedModel {
    let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);
    let cfg = TrainConfig { hidden: 32, layers: 2, lr: 0.05, ..TrainConfig::capgnn(2) };
    let mut backend = NativeBackend::new();
    run(ds, &cluster, &mut backend, &cfg).expect("training failed").1
}

/// One serving run: fresh server, fresh cache, fresh workers.
fn serve_once(
    ds: &Dataset,
    model: &TrainedModel,
    workload: &[u32],
    max_batch: usize,
    cache: usize,
    qps: f64,
) -> DriverReport {
    let cfg = ServeConfig {
        max_batch,
        max_wait_us: 1000,
        workers: 2,
        fanout: Fanout(vec![6, 4]),
        cache_capacity: cache,
        prepopulate: cache / 2,
        seed: 42,
        ..ServeConfig::new(2)
    };
    let mut handle = Server::start(ds, model.clone(), &cfg).expect("server start");
    let rep = run_driver(&mut handle, workload, Pacing::Open { qps }).expect("driver");
    handle.shutdown().expect("shutdown");
    rep
}

fn main() {
    let quick = bench::quick_mode();
    let n = if quick { 2048 } else { 16384 };
    let rates: &[f64] = if quick { &[500.0, 2000.0] } else { &[1000.0, 4000.0] };
    let batch_ceilings: &[usize] = &[8, 64];
    let cache = if quick { 512 } else { 2048 };
    let requests = if quick { 1500 } else { 6000 };

    let ds = make_dataset(n, 42);
    let model = train_model(&ds);
    let workload = zipf_workload(
        &ds.graph,
        &WorkloadConfig { requests, zipf_s: 1.1, hot_ranks: cache, seed: 7 },
    );

    let mut entries: Vec<Json> = Vec::new();
    let mut gate_hits_ok = true;
    let mut gate_p99_ok = true;
    let mut gate_consistent = true;
    for &qps in rates {
        for &mb in batch_ceilings {
            let r = serve_once(&ds, &model, &workload, mb, cache, qps);
            if r.cache_hits == 0 {
                gate_hits_ok = false;
            }
            if r.p99_us > 500_000 {
                gate_p99_ok = false;
            }
            if !r.consistent || r.received != r.sent {
                gate_consistent = false;
            }
            println!(
                "qps={qps} max_batch={mb}: p50 {}µs p99 {}µs mean {:.0}µs, sustained {:.0} rps, \
                 hit rate {:.3} ({} of {} hits)",
                r.p50_us,
                r.p99_us,
                r.mean_us,
                r.sustained_qps,
                r.hit_rate,
                r.cache_hits,
                r.received,
            );
            entries.push(obj(vec![
                ("offered_qps", num(qps)),
                ("max_batch", num(mb as f64)),
                ("requests", num(r.sent as f64)),
                ("p50_us", num(r.p50_us as f64)),
                ("p99_us", num(r.p99_us as f64)),
                ("mean_us", num(r.mean_us)),
                ("max_us", num(r.max_us as f64)),
                ("sustained_qps", num(r.sustained_qps)),
                ("cache_hits", num(r.cache_hits as f64)),
                ("cache_hit_rate", num(r.hit_rate)),
                ("consistent", Json::Bool(r.consistent)),
            ]));
        }
    }

    // Determinism gate: the same stream against two fresh servers (new
    // cache, new workers, new batching timing) must produce bit-equal
    // result sets.
    let a = serve_once(&ds, &model, &workload, batch_ceilings[0], cache, rates[0]);
    let b = serve_once(&ds, &model, &workload, batch_ceilings[0], cache, rates[0]);
    let stable = a.consistent && b.consistent && a.output_digest == b.output_digest;
    if !stable {
        eprintln!(
            "DETERMINISM BREACH: same-seed serving runs differ (digests {:#x} vs {:#x})",
            a.output_digest, b.output_digest
        );
    }

    let mut doc = BenchDoc::new("pr7_serve", "BENCH_PR7.json");
    doc.field("n", num(n as f64));
    doc.field("zipf_s", num(1.1));
    doc.field("results", arr(entries));
    doc.gate(
        "cache_hits_positive",
        gate_hits_ok,
        "CACHE GATE FAILED: a configuration saw zero cross-request cache hits",
    );
    doc.gate("p99_under_500ms", gate_p99_ok, "LATENCY GATE FAILED: p99 exceeded 500ms");
    doc.gate(
        "responses_consistent",
        gate_consistent,
        "CONSISTENCY GATE FAILED: two responses for one vertex differed in a bit",
    );
    doc.gate(
        "bit_stable_across_runs",
        stable,
        "DETERMINISM GATE FAILED: same-seed serving runs produced different digests",
    );
    doc.finish();
}

//! PR9 bench / CI gate: fault injection, recovery parity, and serving
//! degradation.
//!
//! Three scenarios on a two-machine cluster preset:
//!
//! 1. **Chaos training** — the full fault matrix (frame corruption,
//!    drops, delays, transient backend errors, worker panics) against a
//!    clean reference run. The link layer recovers frame faults by CRC +
//!    bounded retransmission; the `--max-retries` budget replays aborted
//!    epochs. Gate: losses, accuracies, and byte accounting are
//!    bit-identical to the clean run, and a nonzero number of faults
//!    actually fired.
//! 2. **Checkpoint → kill → resume** — a run killed after its mid-point
//!    checkpoint and resumed from the `.cgk` artifact. Gate: final
//!    numerics, bytes, and weights match the uninterrupted run bitwise.
//! 3. **Serving degradation** — a one-worker server with injected worker
//!    panics and a bounded admission queue under a burst. Gate: overload
//!    is shed via the typed error, the panicking worker is respawned,
//!    and every non-lost request is answered.
//!
//! Writes `BENCH_PR9.json` to the repo root; exits nonzero if any gate
//! fails. `BENCH_QUICK=1` shrinks the graph for smoke runs.

use capgnn::device::profile::DeviceKind;
use capgnn::dist::Cluster;
use capgnn::fault::FaultPlan;
use capgnn::graph::datasets::synthetic_node_data;
use capgnn::graph::{Dataset, Graph};
use capgnn::model::TrainedModel;
use capgnn::runtime::NativeBackend;
use capgnn::sample::Fanout;
use capgnn::serve::{ServeConfig, ServeError, Server};
use capgnn::train::{run_with, RunOptions, TrainConfig, TrainReport};
use capgnn::util::bench;
use capgnn::util::bench_json::BenchDoc;
use capgnn::util::json::{num, obj, Json};
use capgnn::util::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Random graph (avg degree ≈ 8) with synthetic labeled features.
fn make_dataset(n: usize, seed: u64) -> Dataset {
    let m = n * 8;
    let mut rng = Rng::new(seed);
    let edges: Vec<(u32, u32)> =
        (0..m).map(|_| (rng.index(n) as u32, rng.index(n) as u32)).collect();
    let graph = Graph::from_edges(n, &edges);
    let data = synthetic_node_data(&graph, 8, 32, seed);
    Dataset { name: "bench", label: "Bn", graph, data }
}

fn base_cfg(epochs: usize) -> TrainConfig {
    TrainConfig { hidden: 32, layers: 2, lr: 0.05, ..TrainConfig::capgnn(epochs) }
}

fn run(ds: &Dataset, cluster: &Cluster, cfg: &TrainConfig, opts: RunOptions) -> (TrainReport, TrainedModel) {
    let mut backend = NativeBackend::new();
    let out = run_with(ds, cluster, &mut backend, cfg, opts).expect("run");
    (out.report, out.model)
}

/// Recovery parity: numerics + byte accounting, bitwise.
fn same_outcome(a: &TrainReport, b: &TrainReport) -> bool {
    a.losses == b.losses
        && a.val_accs == b.val_accs
        && a.test_acc.to_bits() == b.test_acc.to_bits()
        && a.bytes_moved == b.bytes_moved
        && a.bytes_saved == b.bytes_saved
        && a.cross_bytes_moved == b.cross_bytes_moved
        && a.cross_bytes_naive == b.cross_bytes_naive
}

fn same_weights(a: &TrainedModel, b: &TrainedModel) -> bool {
    a.model.weights.iter().zip(&b.model.weights).all(|(la, lb)| {
        la.iter()
            .zip(lb)
            .all(|(ra, rb)| ra.iter().zip(rb).all(|(x, y)| x.to_bits() == y.to_bits()))
    })
}

fn main() {
    let quick = bench::quick_mode();
    let n = if quick { 1024 } else { 4096 };
    let epochs = if quick { 4 } else { 6 };
    let cluster = Cluster::preset("2M-2D").unwrap();
    let ds = make_dataset(n, 42);
    let cfg = base_cfg(epochs);

    // ---- 1. Chaos training vs clean reference ---------------------------
    let t0 = std::time::Instant::now();
    let (clean, clean_model) = run(&ds, &cluster, &cfg, RunOptions::default());
    let clean_wall = t0.elapsed().as_secs_f64();

    let fp = Arc::new(
        FaultPlan::parse("seed=13,corrupt=0.2,drop=0.1,delay=0.1,backend=0.3,panic=0.1")
            .unwrap(),
    );
    let mut chaos_cfg = cfg.clone();
    chaos_cfg.fault = Some(fp.clone());
    let t1 = std::time::Instant::now();
    let (chaos, chaos_model) = run(
        &ds,
        &cluster,
        &chaos_cfg,
        RunOptions { max_retries: 4, ..RunOptions::default() },
    );
    let chaos_wall = t1.elapsed().as_secs_f64();
    let c = fp.counters();
    let injected = fp.total_injected();
    let chaos_parity = same_outcome(&clean, &chaos) && same_weights(&clean_model, &chaos_model);
    println!(
        "chaos: {} faults injected ({} corrupt, {} drop, {} delay, {} backend, {} panic; \
         {} retransmissions) — parity {}",
        injected, c.corrupted, c.dropped, c.delayed, c.backend_errs, c.panics, c.retries,
        if chaos_parity { "BIT-IDENTICAL" } else { "DIVERGED" },
    );

    // ---- 2. Checkpoint -> kill -> resume --------------------------------
    let ck_path = std::env::temp_dir()
        .join(format!("capgnn_pr9_bench_{}.cgk", std::process::id()));
    let ck_s = ck_path.to_str().unwrap().to_string();
    let half = epochs / 2;
    let mut cfg_half = cfg.clone();
    cfg_half.epochs = half;
    run(
        &ds,
        &cluster,
        &cfg_half,
        RunOptions {
            checkpoint_every: Some(half as u64),
            checkpoint_path: Some(ck_s.clone()),
            ..RunOptions::default()
        },
    );
    let ck_bytes = std::fs::metadata(&ck_path).map(|m| m.len()).unwrap_or(0);
    let (resumed, resumed_model) = run(
        &ds,
        &cluster,
        &cfg,
        RunOptions { resume: Some(ck_s), ..RunOptions::default() },
    );
    let resume_parity = resumed.losses.len() == epochs
        && same_outcome(&clean, &resumed)
        && same_weights(&clean_model, &resumed_model);
    std::fs::remove_file(&ck_path).ok();
    println!(
        "resume: killed after epoch {half}, resumed from a {ck_bytes}-byte .cgk — parity {}",
        if resume_parity { "BIT-IDENTICAL" } else { "DIVERGED" },
    );

    // ---- 3. Serving degradation -----------------------------------------
    let scfg = ServeConfig {
        fanout: Fanout(vec![6, 4]),
        cache_capacity: 256,
        prepopulate: 0,
        workers: 1,
        max_batch: 1,
        max_wait_us: 100,
        max_queue: 64,
        fault: Some(Arc::new(FaultPlan::parse("seed=3,panic=1.0").unwrap())),
        ..ServeConfig::new(2)
    };
    let burst = 200usize;
    let mut handle = Server::start(&ds, clean_model.clone(), &scfg).expect("server start");
    let mut accepted = 0usize;
    let mut typed_shed = 0usize;
    for v in 0..burst as u32 {
        match handle.submit(v) {
            Ok(_) => accepted += 1,
            Err(e) if e.downcast_ref::<ServeError>().is_some() => typed_shed += 1,
            Err(e) => panic!("untyped submit error: {e}"),
        }
    }
    // Liveness: everything that was admitted (minus the one batch lost to
    // the injected panic) comes back within a bounded wait.
    let mut answered = 0usize;
    while answered + 1 < accepted {
        match handle.recv_timeout(Duration::from_secs(30)) {
            Some(_) => answered += 1,
            None => break,
        }
    }
    let srep = handle.shutdown().expect("shutdown");
    let serve_ok = typed_shed as u64 == srep.shed
        && srep.panics >= 1
        && srep.respawns >= 1
        && srep.responses == accepted as u64 - 1;
    println!(
        "serve: burst {burst} -> {accepted} admitted, {} shed, {} answered after {} panic(s) \
         / {} respawn(s)",
        srep.shed, srep.responses, srep.panics, srep.respawns,
    );

    let mut doc = BenchDoc::new("pr9_faults", "BENCH_PR9.json");
    doc.field("n", num(n as f64));
    doc.field("epochs", num(epochs as f64));
    doc.field(
        "chaos",
        obj(vec![
            ("injected", num(injected as f64)),
            ("corrupted", num(c.corrupted as f64)),
            ("dropped", num(c.dropped as f64)),
            ("delayed", num(c.delayed as f64)),
            ("backend_errors", num(c.backend_errs as f64)),
            ("worker_panics", num(c.panics as f64)),
            ("retransmissions", num(c.retries as f64)),
            ("clean_wall_s", num(clean_wall)),
            ("chaos_wall_s", num(chaos_wall)),
            ("recovery_overhead", num(if clean_wall > 0.0 { chaos_wall / clean_wall } else { 0.0 })),
            ("bit_identical", Json::Bool(chaos_parity)),
        ]),
    );
    doc.field(
        "resume",
        obj(vec![
            ("checkpoint_bytes", num(ck_bytes as f64)),
            ("killed_after_epoch", num(half as f64)),
            ("bit_identical", Json::Bool(resume_parity)),
        ]),
    );
    doc.field(
        "serve",
        obj(vec![
            ("burst", num(burst as f64)),
            ("admitted", num(accepted as f64)),
            ("shed", num(srep.shed as f64)),
            ("answered", num(srep.responses as f64)),
            ("panics", num(srep.panics as f64)),
            ("respawns", num(srep.respawns as f64)),
        ]),
    );
    doc.gate(
        "faults_injected",
        injected > 0,
        "FAULT GATE FAILED: the chaos plan injected nothing — the run was not stressed",
    );
    doc.gate(
        "chaos_recovery_bit_identical",
        chaos_parity,
        "PARITY GATE FAILED: the recovered chaos run diverged from the clean run",
    );
    doc.gate(
        "resume_bit_identical",
        resume_parity,
        "RESUME GATE FAILED: checkpoint -> kill -> resume diverged from the clean run",
    );
    doc.gate(
        "serve_degrades_gracefully",
        serve_ok,
        "SERVE GATE FAILED: overload shedding / worker respawn did not behave",
    );
    doc.finish();
}

//! §Perf micro-benchmarks over the L3 hot paths: native matmul kernels,
//! cache lookup/insert throughput, halo exchange round, partitioners, and
//! the end-to-end epoch. These are *wallclock* benches (unlike the
//! experiment drivers, which report simulated time) — the before/after log
//! in EXPERIMENTS.md §Perf comes from here.

use capgnn::cache::{PolicyKind, TwoLevelCache};
use capgnn::comm::exchange::{ExchangeEngine, ExchangeParams};
use capgnn::device::profile::{DeviceKind, Gpu};
use capgnn::device::topology::Topology;
use capgnn::graph::{spec_by_name, Graph, SparseAdj};
use capgnn::partition::halo::build_plan;
use capgnn::partition::Method;
use capgnn::runtime::native::{matmul, spmm};
use capgnn::runtime::{Backend, NativeBackend};
use capgnn::train::{run, TrainConfig};
use capgnn::util::bench::run_bench;
use capgnn::util::Rng;

/// FLOP throughput of `flops` useful floating-point ops in `secs`.
fn gflops(flops: usize, secs: f64) -> f64 {
    flops as f64 / secs.max(1e-12) / 1e9
}

fn main() {
    let mut rng = Rng::new(1);

    // L3 kernel: dense matmul at trainer shapes.
    for (n, k, m) in [(1024usize, 1024usize, 64usize), (512, 512, 64)] {
        let x: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; n * m];
        let sum = run_bench(&format!("native_matmul_{n}x{k}x{m}"), || {
            matmul(n, k, m, &x, &y, &mut out);
            std::hint::black_box(&out);
        });
        println!("  throughput: {:.2} GFLOP/s", gflops(2 * n * k * m, sum.mean));
    }

    // Sparse-style matmul (zero-skipping path) at adjacency density ~1%.
    {
        let n = 1024usize;
        let mut a = vec![0.0f32; n * n];
        for _ in 0..(n * n / 100) {
            let i = rng.index(n);
            let j = rng.index(n);
            a[i * n + j] = 0.5;
        }
        let h: Vec<f32> = (0..n * 64).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; n * 64];
        let nnz = a.iter().filter(|&&v| v != 0.0).count();
        let sum = run_bench("native_aggregation_sparse_1pct_1024", || {
            matmul(n, n, 64, &a, &h, &mut out);
            std::hint::black_box(&out);
        });
        // Effective FLOPs only — the zero-skipping path does no work on
        // the ~99% empty entries, so the useful rate is over nnz.
        println!("  throughput: {:.2} GFLOP/s effective", gflops(2 * nnz * 64, sum.mean));
    }

    // SpMM kernels (PR4): CSR aggregation at trainer shapes — forward,
    // transposed (backward) and row-block parallel variants. Compare with
    // the dense zero-skipping aggregation above.
    {
        let n = 4096usize;
        let g = Graph::random(n, 4 * n, &mut rng);
        let adj = SparseAdj::gcn_normalized(&g, n);
        let h: Vec<f32> = (0..n * 64).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; n * 64];
        for threads in [1usize, 2, 4] {
            let sum = run_bench(&format!("spmm_gcn_{n}x64_t{threads}"), || {
                spmm(adj.fwd(), 64, &h, &mut out, threads);
                std::hint::black_box(&out);
            });
            println!("  throughput: {:.2} GFLOP/s", gflops(2 * adj.nnz() * 64, sum.mean));
        }
        let t = adj.transpose();
        let sum = run_bench(&format!("spmm_t_gcn_{n}x64"), || {
            spmm(t, 64, &h, &mut out, 1);
            std::hint::black_box(&out);
        });
        println!("  throughput: {:.2} GFLOP/s", gflops(2 * adj.nnz() * 64, sum.mean));
    }

    // Cache throughput.
    {
        let mut cache = TwoLevelCache::new(PolicyKind::Jaca, &[4096; 4], 16384);
        for k in 0..16384u64 {
            cache.set_priority((k % 4) as usize, k, (k % 7) as u32 + 1);
        }
        run_bench("cache_lookup_fill_16k", || {
            for k in 0..16384u64 {
                let w = (k % 4) as usize;
                if cache.lookup(w, k) == capgnn::cache::twolevel::Hit::Miss {
                    cache.fill(w, k, vec![1.0; 16], 0);
                }
            }
        });
    }

    // Partitioners on the Reddit twin.
    let ds = spec_by_name("Rt").unwrap().build_scaled(42, 0.5);
    for method in [Method::Metis, Method::Fennel, Method::Random] {
        run_bench(&format!("partition_{}_rt", method.name()), || {
            let mut r = Rng::new(3);
            std::hint::black_box(method.partition(&ds.graph, 4, &mut r));
        });
    }

    // One halo-exchange round.
    {
        let mut r = Rng::new(4);
        let ps = Method::Metis.partition(&ds.graph, 4, &mut r);
        let plan = build_plan(&ds.graph, &ps);
        let gpus: Vec<Gpu> = (0..4).map(|i| Gpu::new(i, DeviceKind::Rtx3090, &mut r)).collect();
        let topo = Topology::pcie_pairs(4);
        let eng = ExchangeEngine::new(&gpus, &topo);
        let caps: Vec<usize> = plan.parts.iter().map(|p| p.n_halo()).collect();
        let total = caps.iter().sum();
        let mut cache = TwoLevelCache::new(PolicyKind::Jaca, &caps, total);
        run_bench("halo_exchange_round_rt", || {
            let rep = eng.exchange(
                &plan,
                &mut cache,
                ExchangeParams::new(0, 0, 64),
                |v| vec![v as f32; 64],
                |_, _, row| {
                    std::hint::black_box(row);
                },
            );
            std::hint::black_box(rep.bytes_moved);
        });
    }

    // End-to-end epoch (native backend), the trainer hot loop.
    {
        let gpus: Vec<Gpu> = {
            let mut r = Rng::new(5);
            (0..4).map(|i| Gpu::new(i, DeviceKind::Rtx3090, &mut r)).collect()
        };
        let topo = Topology::pcie_pairs(4);
        let cfg = TrainConfig { epochs: 1, ..TrainConfig::capgnn(1) };
        let cluster = capgnn::dist::Cluster::from_parts(gpus, topo).unwrap();
        let mut backend = NativeBackend::new();
        run_bench("train_epoch_rt_x4_native", || {
            let rep = run(&ds, &cluster, &mut backend, &cfg).unwrap().0;
            std::hint::black_box(rep.total_time());
        });
        let _ = backend.name();
    }
}

//! PR6 bench / CI gate: mini-batch neighbor-sampled training vs the
//! full-batch trainer.
//!
//! For three graph sizes × two batch sizes (2 workers, 2 layers, fanout
//! 8,4) it measures the sampled per-epoch wall time against the
//! full-batch epoch on the same graph, and records the sampled path's
//! memory story: peak resident subgraph size (vertices and bytes) and
//! the per-epoch touched-vertex count.
//!
//! Writes `BENCH_PR6.json` to the repo root, then exits nonzero if
//! - at the largest size with the smallest batch, the peak resident
//!   block reaches the full graph (sampling must bound the working set
//!   below |V|), or
//! - the per-epoch touched-vertex metric is missing/out of range, or
//! - two fresh same-seed sampled runs differ in any loss bit
//!   (the determinism contract the tests assert, re-checked here on a
//!   bench-scale graph).
//!
//! `BENCH_QUICK=1` shrinks the sizes for smoke runs.

use capgnn::device::profile::DeviceKind;
use capgnn::dist::Cluster;
use capgnn::graph::datasets::synthetic_node_data;
use capgnn::graph::{Dataset, Graph};
use capgnn::runtime::NativeBackend;
use capgnn::train::{SampledSession, Session, TrainConfig, TrainMode, TrainReport};
use capgnn::util::bench;
use capgnn::util::bench_json::BenchDoc;
use capgnn::util::json::{arr, num, obj, Json};
use capgnn::util::Rng;

/// Random graph (avg degree ≈ 8) with synthetic labeled features.
fn make_dataset(n: usize, seed: u64) -> Dataset {
    let m = n * 8;
    let mut rng = Rng::new(seed);
    let edges: Vec<(u32, u32)> =
        (0..m).map(|_| (rng.index(n) as u32, rng.index(n) as u32)).collect();
    let graph = Graph::from_edges(n, &edges);
    let data = synthetic_node_data(&graph, 8, 32, seed);
    Dataset { name: "bench", label: "Bn", graph, data }
}

fn sampled_cfg(batch_size: usize) -> TrainConfig {
    TrainConfig {
        hidden: 32,
        layers: 2,
        lr: 0.05,
        mode: TrainMode::Sampled,
        batch_size,
        fanout: vec![8, 4],
        ..TrainConfig::capgnn(4)
    }
}

fn full_cfg() -> TrainConfig {
    TrainConfig { hidden: 32, layers: 2, lr: 0.05, ..TrainConfig::capgnn(4) }
}

/// Train `epochs` sampled epochs from scratch and return the report.
fn run_sampled(ds: &Dataset, cl: &Cluster, batch_size: usize, epochs: usize) -> TrainReport {
    let mut backend = NativeBackend::new();
    let cfg = sampled_cfg(batch_size);
    let mut session = SampledSession::build(ds, cl, &mut backend, &cfg).unwrap();
    session.run_epochs(epochs).unwrap();
    session.finish().unwrap().0
}

fn main() {
    let quick = bench::quick_mode();
    let sizes: &[usize] = if quick { &[1024, 2048, 4096] } else { &[8192, 16384, 32768] };
    let batch_sizes: &[usize] = &[64, 256];
    let reps = if quick { 1 } else { 2 };
    let cl = Cluster::homogeneous(DeviceKind::Rtx3090, 2, 7);

    let mut entries: Vec<Json> = Vec::new();
    let mut gate_peak_ok = true;
    let mut gate_touched_ok = true;
    for &n in sizes {
        let ds = make_dataset(n, 42);

        // Full-batch reference epoch on the same graph (one config — the
        // batch size does not exist there).
        let mut backend = NativeBackend::new();
        let cfg = full_cfg();
        let mut full = Session::build(&ds, &cl, &mut backend, &cfg).unwrap();
        let full_epoch = bench::measure(
            || {
                full.run_epoch().unwrap();
            },
            0,
            reps,
        );

        for &bs in batch_sizes {
            let mut backend = NativeBackend::new();
            let cfg = sampled_cfg(bs);
            let mut session = SampledSession::build(&ds, &cl, &mut backend, &cfg).unwrap();
            let sampled_epoch = bench::measure(
                || {
                    session.run_epoch().unwrap();
                },
                0,
                reps,
            );
            let r = session.finish().unwrap().0;

            let touched_mean = r.epoch_touched.iter().sum::<u64>() as f64
                / r.epoch_touched.len().max(1) as f64;
            // The sampled working set must stay below the full graph at
            // the largest size with the smallest batch — otherwise
            // mini-batching buys no memory headroom.
            if n == *sizes.last().unwrap() && bs == batch_sizes[0] && r.peak_block_vertices >= n {
                gate_peak_ok = false;
            }
            if r.epoch_touched.is_empty()
                || r.epoch_touched.iter().any(|&t| t == 0 || t > n as u64)
            {
                gate_touched_ok = false;
            }

            println!(
                "n={n} bs={bs}: sampled epoch {:.4}s ({} batches, peak block {} vertices, \
                 {:.2} MiB resident, touched/epoch {:.0} of {n}) vs full-batch {:.4}s",
                sampled_epoch.mean,
                r.batches_per_epoch,
                r.peak_block_vertices,
                r.peak_block_bytes as f64 / (1024.0 * 1024.0),
                touched_mean,
                full_epoch.mean,
            );
            entries.push(obj(vec![
                ("n", num(n as f64)),
                ("batch_size", num(bs as f64)),
                ("sampled_epoch_s", num(sampled_epoch.mean)),
                ("full_epoch_s", num(full_epoch.mean)),
                ("batches_per_epoch", num(r.batches_per_epoch as f64)),
                ("peak_block_vertices", num(r.peak_block_vertices as f64)),
                ("peak_block_bytes", num(r.peak_block_bytes as f64)),
                ("epoch_touched_mean", num(touched_mean)),
                ("sampled_vertices_total", num(r.sampled_vertices as f64)),
                ("cache_hit_rate", num(r.cache.hit_rate())),
            ]));
        }
    }

    // Determinism gate: two fresh same-seed sampled runs on the smallest
    // bench graph must agree on every loss bit.
    let ds = make_dataset(sizes[0], 42);
    let a = run_sampled(&ds, &cl, batch_sizes[0], 2);
    let b = run_sampled(&ds, &cl, batch_sizes[0], 2);
    let stable = a.losses == b.losses && a.val_accs == b.val_accs;
    if !stable {
        eprintln!(
            "DETERMINISM BREACH: same-seed sampled runs differ ({:?} vs {:?})",
            a.losses, b.losses
        );
    }

    let mut doc = BenchDoc::new("pr6_sample", "BENCH_PR6.json");
    doc.field("results", arr(entries));
    doc.gate(
        "peak_block_below_full_graph",
        gate_peak_ok,
        "SUBGRAPH GATE FAILED: peak resident block reached the full graph at the \
         largest size with the smallest batch — sampling must bound the working set",
    );
    doc.gate(
        "epoch_touched_in_range",
        gate_touched_ok,
        "TOUCHED GATE FAILED: per-epoch touched-vertex metric missing or out of range",
    );
    doc.gate(
        "bit_stable_across_runs",
        stable,
        "DETERMINISM GATE FAILED: same-seed sampled runs disagreed on a loss bit",
    );
    doc.finish();
}

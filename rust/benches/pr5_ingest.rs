//! PR5 bench / CI gate: edge-list ingestion and parallel CSR assembly.
//!
//! For three graph sizes, generates a text edge list and measures
//! - parse throughput (`read_edge_list`, streaming line parser);
//! - CSR build throughput (`build_csr` two-pass counting sort) at 1, 2
//!   and 4 row-block threads.
//!
//! Writes `BENCH_PR5.json` (per-size times + edges/sec) to the repo
//! root, then exits nonzero if at the largest size either
//! - the 2-thread build is slower than single-threaded (>10% tolerance —
//!   2 threads so the gate holds on 2-core CI runners; the 4-thread
//!   time is reported, not gated), or
//! - the parallel CSR differs from the single-threaded CSR in any bit, or
//! - a save → load `.cgr` round-trip is not bit-exact.
//!
//! `BENCH_QUICK=1` shrinks the sizes for smoke runs (the speed gate is
//! skipped there: at toy sizes thread spawn overhead dominates).

use capgnn::graph::io::{build_csr, load_cgr, read_edge_list, save_cgr, write_edge_list};
use capgnn::util::bench;
use capgnn::util::bench_json::BenchDoc;
use capgnn::util::json::{arr, num, obj, Json};
use capgnn::util::Rng;

fn main() {
    let quick = bench::quick_mode();
    // (vertices, edge records): avg degree ≈ 8 at every size.
    let sizes: &[(usize, usize)] = if quick {
        &[(1024, 4096), (2048, 8192), (4096, 16384)]
    } else {
        &[(16384, 65536), (65536, 262144), (131072, 524288)]
    };
    let reps = if quick { 2 } else { 3 };
    let _ = std::fs::create_dir_all("target");

    let mut entries: Vec<Json> = Vec::new();
    let mut last_build1 = 0.0f64;
    let mut last_build2 = 0.0f64;
    for &(n, m) in sizes {
        let mut rng = Rng::new(42);
        let edges: Vec<(u32, u32)> =
            (0..m).map(|_| (rng.index(n) as u32, rng.index(n) as u32)).collect();
        let mut text: Vec<u8> = Vec::with_capacity(m * 12);
        write_edge_list(&mut text, &edges).unwrap();

        // Parse throughput (streaming line parser over the in-memory file
        // image — no disk noise in the number).
        let mut parsed = None;
        let parse = bench::measure(
            || {
                parsed = Some(read_edge_list(text.as_slice(), Some(n)).unwrap());
            },
            1,
            reps,
        );
        let list = parsed.expect("parsed edge list");

        // CSR build: single-threaded reference, then row-block parallel.
        let mut g1 = None;
        let build1 = bench::measure(
            || {
                g1 = Some(build_csr(n, &list.edges, 1).unwrap().0);
            },
            1,
            reps,
        );
        let mut g2 = None;
        let build2 = bench::measure(
            || {
                g2 = Some(build_csr(n, &list.edges, 2).unwrap().0);
            },
            1,
            reps,
        );
        let mut g4 = None;
        let build4 = bench::measure(
            || {
                g4 = Some(build_csr(n, &list.edges, 4).unwrap().0);
            },
            1,
            reps,
        );
        let (g1, g2, g4) = (g1.unwrap(), g2.unwrap(), g4.unwrap());
        if g2 != g1 || g4 != g1 {
            eprintln!("DETERMINISM BREACH at n={n}: parallel CSR differs from single-threaded");
            std::process::exit(1);
        }

        println!(
            "n={n} m={m} ({} bytes of text): parse {:.4}s ({:.2}M edges/s), \
             build t1 {:.4}s, t2 {:.4}s, t4 {:.4}s ({:.2}x at t2)",
            text.len(),
            parse.mean,
            m as f64 / parse.mean.max(1e-12) / 1e6,
            build1.mean,
            build2.mean,
            build4.mean,
            build1.mean / build2.mean.max(1e-12),
        );
        entries.push(obj(vec![
            ("n", num(n as f64)),
            ("m", num(m as f64)),
            ("text_bytes", num(text.len() as f64)),
            ("parse_s", num(parse.mean)),
            ("parse_edges_per_s", num(m as f64 / parse.mean.max(1e-12))),
            ("build_s_t1", num(build1.mean)),
            ("build_s_t2", num(build2.mean)),
            ("build_s_t4", num(build4.mean)),
            ("build_edges_per_s_t1", num(m as f64 / build1.mean.max(1e-12))),
            ("build_edges_per_s_t2", num(m as f64 / build2.mean.max(1e-12))),
            ("parallel_speedup_t2", num(build1.mean / build2.mean.max(1e-12))),
        ]));
        last_build1 = build1.mean;
        last_build2 = build2.mean;
    }

    // Round-trip gate at the largest size: ingest → save → load must be
    // bit-exact (Graph stores no floats; exact equality is the bar).
    let (n, m) = *sizes.last().unwrap();
    let mut rng = Rng::new(42);
    let edges: Vec<(u32, u32)> =
        (0..m).map(|_| (rng.index(n) as u32, rng.index(n) as u32)).collect();
    let (g, _) = build_csr(n, &edges, 4).unwrap();
    let path = "target/pr5_ingest.cgr";
    save_cgr(std::path::Path::new(path), &g, None).unwrap();
    let back = load_cgr(std::path::Path::new(path)).unwrap();
    let roundtrip_ok = back.graph == g;
    let parallel_ratio = last_build2 / last_build1.max(1e-12);
    let mut doc = BenchDoc::new("pr5_ingest", "BENCH_PR5.json");
    doc.field("results", arr(entries));
    doc.field("parallel_ratio_t2_at_largest", num(parallel_ratio));
    doc.gate(
        "roundtrip_bit_exact",
        roundtrip_ok,
        &format!("ROUND-TRIP BREACH at n={n}: .cgr load differs from the saved graph"),
    );
    if quick {
        println!("quick mode: parallel speed gate skipped (toy sizes)");
    } else {
        doc.gate(
            "parallel_no_slower_t2",
            parallel_ratio <= 1.10,
            &format!(
                "PERF GATE FAILED: 2-thread CSR build is {:.0}% slower than single-threaded \
                 at the largest size (must be no slower, 10% tolerance)",
                (parallel_ratio - 1.0) * 100.0
            ),
        );
    }
    doc.finish();
}

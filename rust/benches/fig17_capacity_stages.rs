//! Bench target for the paper's Figs. 17-18 (stage breakdown vs capacities).
//! Prints the same rows/series the paper reports; timing via the
//! hand-rolled harness (criterion unavailable offline — DESIGN.md S6).

use capgnn::expt::{self, Ctx};
use capgnn::util::bench::run_expt_bench;

fn main() {
    let ctx = if capgnn::util::bench::quick_mode() { Ctx::quick() } else { Ctx { scale: 0.3, epochs: 5, seed: 42, dataset: None } };
    run_expt_bench("fig17", || {
        expt::cache_expts::fig17_18(ctx);
    });
}

//! PR10 bench / CI gate: dynamic graphs — incremental edge updates with
//! cache invalidation.
//!
//! At three graph sizes:
//!
//! 1. **Update-apply throughput** — a `DeltaGraph` absorbs the full
//!    update stream (inserts, deletes, redundant ops, self-loops) and
//!    materializes a canonical snapshot; reported as updates/second
//!    against the equivalent from-scratch rebuild.
//! 2. **Interleaved update+train** — the same stream applied at update
//!    points inside a training run ([`GraphMode::Delta`]) versus the
//!    rebuild-from-scratch reference arm ([`GraphMode::Rebuild`]).
//!    Gate: every observable (losses, accuracies, bytes, cache counters,
//!    invalidation totals, drift decisions, final weights) is
//!    bit-identical — and the update points provably invalidated stale
//!    cached rows (invalidations > 0).
//!
//! Writes `BENCH_PR10.json` to the repo root; exits nonzero if any gate
//! fails. `BENCH_QUICK=1` shrinks the graphs for smoke runs.

use capgnn::dist::Cluster;
use capgnn::graph::delta::{DeltaGraph, Update, UpdateBatch};
use capgnn::graph::datasets::synthetic_node_data;
use capgnn::graph::{Dataset, Graph};
use capgnn::runtime::NativeBackend;
use capgnn::train::{run_dynamic, DynamicConfig, DynamicOutcome, GraphMode, TrainConfig};
use capgnn::util::bench;
use capgnn::util::bench_json::BenchDoc;
use capgnn::util::json::{num, obj, Json};
use capgnn::util::Rng;
use std::collections::BTreeSet;
use std::time::Instant;

/// Random graph (avg degree ≈ 8) with synthetic labeled features.
fn make_dataset(n: usize, seed: u64) -> Dataset {
    let m = n * 8;
    let mut rng = Rng::new(seed);
    let edges: Vec<(u32, u32)> =
        (0..m).map(|_| (rng.index(n) as u32, rng.index(n) as u32)).collect();
    let graph = Graph::from_edges(n, &edges);
    let data = synthetic_node_data(&graph, 8, 32, seed);
    Dataset { name: "bench", label: "Bn", graph, data }
}

fn base_cfg(epochs: usize) -> TrainConfig {
    TrainConfig { hidden: 32, layers: 2, lr: 0.05, ..TrainConfig::capgnn(epochs) }
}

/// Update stream: random churn plus guaranteed-effective deletions of
/// existing edges (so every batch touches resident halo vertices and the
/// invalidation path actually fires).
fn make_batches(g: &Graph, batches: usize, per_batch: usize, seed: u64) -> Vec<UpdateBatch> {
    let n = g.n();
    let mut rng = Rng::new(seed);
    (0..batches)
        .map(|b| {
            let mut batch = UpdateBatch::new();
            for i in 0..per_batch {
                if i % 4 == 0 {
                    // Effective deletion of a real edge.
                    let u = ((b * per_batch + i) * 7 % n) as u32;
                    if let Some(&v) = g.nbrs(u).first() {
                        batch.push(Update::Delete(u, v));
                        continue;
                    }
                }
                let u = rng.index(n) as u32;
                let v = if rng.index(10) == 0 { u } else { rng.index(n) as u32 };
                batch.push(if rng.index(2) == 0 {
                    Update::Insert(u, v)
                } else {
                    Update::Delete(u, v)
                });
            }
            batch
        })
        .collect()
}

/// Delta arm vs from-scratch rebuild at the graph level, timed.
fn apply_throughput(g: &Graph, batches: &[UpdateBatch]) -> (f64, f64, bool) {
    let total_updates: usize = batches.iter().map(|b| b.len()).sum();

    let t0 = Instant::now();
    let mut dg = DeltaGraph::new(g.clone());
    for b in batches {
        dg.apply(b).expect("apply");
    }
    let delta_graph = dg.snapshot();
    let delta_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let mut edges: BTreeSet<(u32, u32)> = BTreeSet::new();
    for u in 0..g.n() as u32 {
        for &v in g.nbrs(u) {
            if u < v {
                edges.insert((u, v));
            }
        }
    }
    let mut rebuilt = g.clone();
    for b in batches {
        for up in b {
            let (u, v) = up.endpoints();
            if u == v {
                continue;
            }
            let e = (u.min(v), u.max(v));
            match up {
                Update::Insert(..) => edges.insert(e),
                Update::Delete(..) => edges.remove(&e),
            };
        }
        let list: Vec<(u32, u32)> = edges.iter().copied().collect();
        rebuilt = Graph::from_edges(g.n(), &list);
    }
    let rebuild_s = t1.elapsed().as_secs_f64();

    let ups = |s: f64| if s > 0.0 { total_updates as f64 / s } else { 0.0 };
    (ups(delta_s), ups(rebuild_s), delta_graph == rebuilt)
}

fn same_outcome(a: &DynamicOutcome, b: &DynamicOutcome) -> bool {
    let w = |m: &capgnn::model::TrainedModel| -> Vec<u32> {
        m.model
            .weights
            .iter()
            .flatten()
            .flatten()
            .map(|x| x.to_bits())
            .collect()
    };
    a.report.losses.iter().map(|x| x.to_bits()).eq(b.report.losses.iter().map(|x| x.to_bits()))
        && a.report.test_acc.to_bits() == b.report.test_acc.to_bits()
        && a.report.bytes_moved == b.report.bytes_moved
        && a.report.bytes_saved == b.report.bytes_saved
        && a.report.cache == b.report.cache
        && a.invalidated == b.invalidated
        && a.repartitions == b.repartitions
        && a.touched == b.touched
        && a.drift == b.drift
        && w(&a.model) == w(&b.model)
}

fn main() {
    let quick = bench::quick_mode();
    let sizes: [usize; 3] = if quick { [256, 512, 1024] } else { [1024, 2048, 4096] };
    let epochs = if quick { 4 } else { 6 };
    let cluster = Cluster::preset("2M-2D").unwrap();

    let mut doc = BenchDoc::new("pr10_dynamic", "BENCH_PR10.json");
    doc.field("epochs", num(epochs as f64));
    doc.field("sizes", Json::Array(sizes.iter().map(|&n| num(n as f64)).collect()));

    let mut all_identical = true;
    let mut all_equivalent = true;
    let mut total_invalidated = 0u64;
    let mut rows = Vec::new();

    for (i, &n) in sizes.iter().enumerate() {
        let ds = make_dataset(n, 42 + i as u64);
        let batches = make_batches(&ds.graph, 2, (n / 8).max(16), 7 + i as u64);
        let n_updates: usize = batches.iter().map(|b| b.len()).sum();

        let (delta_ups, rebuild_ups, graphs_equal) = apply_throughput(&ds.graph, &batches);
        all_equivalent &= graphs_equal;

        let cfg = base_cfg(epochs);
        let dyn_cfg = DynamicConfig {
            batches: batches.clone(),
            update_every: 2,
            ..DynamicConfig::default()
        };
        let t0 = Instant::now();
        let mut b1 = NativeBackend::new();
        let delta =
            run_dynamic(&ds, &cluster, &mut b1, &cfg, &dyn_cfg, GraphMode::Delta).expect("delta");
        let delta_wall = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let mut b2 = NativeBackend::new();
        let rebuild = run_dynamic(&ds, &cluster, &mut b2, &cfg, &dyn_cfg, GraphMode::Rebuild)
            .expect("rebuild");
        let rebuild_wall = t1.elapsed().as_secs_f64();

        let identical = same_outcome(&delta, &rebuild);
        all_identical &= identical;
        total_invalidated += delta.invalidated;

        println!(
            "n={n}: {n_updates} updates | apply {:.0}/s (rebuild {:.0}/s) | \
             interleaved epoch {:.4}s delta vs {:.4}s rebuild | {} rows invalidated, \
             {} repartition(s) — {}",
            delta_ups,
            rebuild_ups,
            delta_wall / epochs as f64,
            rebuild_wall / epochs as f64,
            delta.invalidated,
            delta.repartitions,
            if identical { "BIT-IDENTICAL" } else { "DIVERGED" },
        );

        rows.push(obj(vec![
            ("n", num(n as f64)),
            ("updates", num(n_updates as f64)),
            ("delta_updates_per_s", num(delta_ups)),
            ("rebuild_updates_per_s", num(rebuild_ups)),
            ("delta_epoch_s", num(delta_wall / epochs as f64)),
            ("rebuild_epoch_s", num(rebuild_wall / epochs as f64)),
            ("invalidated_rows", num(delta.invalidated as f64)),
            ("repartitions", num(delta.repartitions as f64)),
            ("effective_inserts", num(delta.stats.inserts as f64)),
            ("effective_deletes", num(delta.stats.deletes as f64)),
            ("redundant", num(delta.stats.redundant as f64)),
            ("self_loops", num(delta.stats.self_loops as f64)),
            ("bit_identical", Json::Bool(identical)),
        ]));
    }
    doc.field("runs", Json::Array(rows));
    doc.field("total_invalidated", num(total_invalidated as f64));

    doc.gate(
        "delta_equals_rebuild_graphs",
        all_equivalent,
        "DELTA GATE FAILED: incremental snapshots diverged from from-scratch rebuilds",
    );
    doc.gate(
        "delta_equals_rebuild_runs",
        all_identical,
        "EQUIVALENCE GATE FAILED: a delta-maintained run diverged from the rebuild arm",
    );
    doc.gate(
        "invalidations_nonzero",
        total_invalidated > 0,
        "INVALIDATION GATE FAILED: no cached row was ever invalidated — stale rows survived",
    );
    doc.finish();
}

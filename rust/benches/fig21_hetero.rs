//! Bench target for the paper's Fig. 21 (heterogeneous robustness).
//! Prints the same rows/series the paper reports; timing via the
//! hand-rolled harness (criterion unavailable offline — DESIGN.md S6).

use capgnn::expt::{self, Ctx};
use capgnn::util::bench::run_expt_bench;

fn main() {
    let ctx = if capgnn::util::bench::quick_mode() { Ctx::quick() } else { Ctx { scale: 0.35, epochs: 8, seed: 42, dataset: None } };
    run_expt_bench("fig21", || {
        expt::rapa_expts::fig21(ctx);
    });
}

//! PR3 CI gate: cross-machine byte accounting of the distributed
//! executor (paper §7 / Table 9).
//!
//! Runs the Table-9 cluster presets on a synthetic twin and writes
//! `BENCH_PR3.json` with the cross-machine wire bytes measured from
//! serialized frames (machine-granularity halo dedup + hierarchical
//! all-reduce) next to the naive per-worker baseline. Exits nonzero if
//! - a single-machine preset reports any cross-machine bytes,
//! - a multi-machine preset reports none,
//! - machine dedup fails to *strictly* reduce cross-machine bytes vs the
//!   naive path on any multi-machine preset, or
//! - the threaded executor disagrees with the sequential reference on
//!   losses or any byte counter (bit-identity breach).
//!
//! `BENCH_QUICK=1` shrinks the workload for smoke runs.

use capgnn::dist::{train_distributed, Cluster};
use capgnn::graph::DatasetSpec;
use capgnn::runtime::NativeBackend;
use capgnn::train::{ExecMode, TrainConfig};
use capgnn::util::bench;
use capgnn::util::bench_json::BenchDoc;
use capgnn::util::json::{arr, num, obj, s, Json};

fn main() {
    let quick = bench::quick_mode();
    let spec = DatasetSpec {
        name: "bench-dist",
        label: "Bd",
        n: if quick { 512 } else { 1024 },
        deg_in: 12.0,
        deg_out: 6.0,
        skew: 1.4,
        classes: 8,
        f_dim: 32,
        orig_nodes: 0,
        orig_edges: 0,
    };
    let ds = spec.build(42);
    let epochs = if quick { 2 } else { 3 };
    println!(
        "pr3_dist_bytes: {} vertices, {} edges, {} epochs per run",
        ds.graph.n(),
        ds.graph.m(),
        epochs
    );

    let mut entries: Vec<Json> = Vec::new();
    let mut failed = false;
    for preset in ["1M-4D", "2M-2D", "2M-4D"] {
        let cluster = Cluster::preset(preset).unwrap();
        // Vanilla communication (cache off) keeps cross-machine traffic
        // on every epoch, so the dedup effect is measured, not a
        // cold-start artifact.
        let mut cfg = TrainConfig::vanilla(epochs);
        cfg.hidden = 32;
        cfg.layers = 2;
        cfg.lr = 0.05;
        let run = |exec: ExecMode| {
            let mut cfg = cfg.clone();
            cfg.exec = exec;
            let mut backend = NativeBackend::new();
            train_distributed(&ds, &cluster, &mut backend, &cfg).expect("dist run")
        };
        let seq = run(ExecMode::Sequential);
        let thr = run(ExecMode::Threaded);
        if seq.report.losses != thr.report.losses
            || seq.cross_machine_bytes != thr.cross_machine_bytes
            || seq.report.bytes_moved != thr.report.bytes_moved
        {
            eprintln!(
                "NUMERICS DIVERGED on {preset}: losses {:?} vs {:?}, cross {} vs {}",
                seq.report.losses, thr.report.losses, seq.cross_machine_bytes,
                thr.cross_machine_bytes
            );
            failed = true;
        }
        let (xb, xn) = (seq.cross_machine_bytes, seq.cross_machine_bytes_naive);
        let savings = seq.report.cross_savings() * 100.0;
        println!(
            "{preset}: {} workers / {} machines — cross {} bytes (naive {}, saved {savings:.1}%)",
            seq.workers, seq.machines, xb, xn
        );
        if seq.machines == 1 {
            if xb != 0 || xn != 0 {
                eprintln!("GATE FAILED: single machine reported cross bytes ({xb}/{xn})");
                failed = true;
            }
        } else {
            if xb == 0 {
                eprintln!("GATE FAILED: {preset} moved no cross-machine bytes");
                failed = true;
            }
            if xb >= xn {
                eprintln!(
                    "GATE FAILED: machine dedup did not reduce cross bytes on {preset}: {xb} >= {xn}"
                );
                failed = true;
            }
        }
        entries.push(obj(vec![
            ("preset", s(preset)),
            ("workers", num(seq.workers as f64)),
            ("machines", num(seq.machines as f64)),
            ("epochs", num(epochs as f64)),
            ("cross_bytes", num(xb as f64)),
            ("cross_bytes_naive", num(xn as f64)),
            ("savings_pct", num(savings)),
            ("bytes_moved", num(seq.report.bytes_moved as f64)),
            ("epochs_per_sec", num(seq.epochs_per_sec)),
        ]));
    }

    let mut doc = BenchDoc::new("pr3_dist_bytes", "BENCH_PR3.json");
    doc.field("graph_n", num(ds.graph.n() as f64));
    doc.field("graph_m", num(ds.graph.m() as f64));
    doc.field("results", arr(entries));
    doc.gate(
        "dedup_reduces_cross_bytes",
        !failed,
        "BYTE GATES FAILED: see the messages above",
    );
    doc.finish();
}

//! PR2 bench-smoke / CI perf gate: measured wall-clock of the threaded
//! epoch executor vs the sequential reference on a synthetic graph at
//! 1/2/4 workers.
//!
//! Writes `BENCH_PR2.json` (epoch wall-clock, speedup, bytes moved) to the
//! repo root and exits nonzero if either
//! - the threaded executor is >10% slower than sequential at 4 workers, or
//! - the two executors disagree on losses or bytes (bit-identity breach).
//!
//! `BENCH_QUICK=1` shrinks the workload for smoke runs.

use capgnn::device::profile::DeviceKind;
use capgnn::dist::Cluster;
use capgnn::graph::DatasetSpec;
use capgnn::runtime::NativeBackend;
use capgnn::train::{ExecMode, Session, TrainConfig};
use capgnn::util::bench;
use capgnn::util::bench_json::BenchDoc;
use capgnn::util::json::{arr, num, obj, Json};

fn main() {
    let quick = bench::quick_mode();
    // Synthetic benchmark graph, dense enough that per-worker layer
    // compute dominates the epoch — the measured speedup then reflects
    // parallel execution rather than exchange bookkeeping.
    let spec = DatasetSpec {
        name: "bench-synth",
        label: "Bs",
        n: if quick { 768 } else { 2048 },
        deg_in: 16.0,
        deg_out: 8.0,
        skew: 1.5,
        classes: 8,
        f_dim: 64,
        orig_nodes: 0,
        orig_edges: 0,
    };
    let ds = spec.build(42);
    let epochs = if quick { 2 } else { 3 };
    println!(
        "pr2_exec_speedup: {} vertices, {} edges, {} epochs per run",
        ds.graph.n(),
        ds.graph.m(),
        epochs
    );

    let mut entries: Vec<Json> = Vec::new();
    let mut seq4 = 0.0f64;
    let mut thr4 = 0.0f64;
    let mut speedup4 = 0.0f64;
    for &workers in &[1usize, 2, 4] {
        let cluster = Cluster::homogeneous(DeviceKind::Rtx3090, workers, 7);
        let base = TrainConfig {
            hidden: if quick { 32 } else { 64 },
            layers: 3,
            lr: 0.05,
            use_rapa: false,
            ..TrainConfig::capgnn(epochs)
        };
        let run_once = |mode: ExecMode| -> (f64, Vec<f32>, u64) {
            let mut cfg = base.clone();
            cfg.exec = mode;
            let mut backend = NativeBackend::new();
            let mut session =
                Session::build(&ds, &cluster, &mut backend, &cfg).expect("session build");
            let t0 = std::time::Instant::now();
            session.run_epochs(epochs).expect("epochs");
            let wall = t0.elapsed().as_secs_f64();
            let report = session.finish().expect("finish").0;
            (wall, report.losses, report.bytes_moved)
        };
        // Two repetitions per mode, gating on the min: shields the CI
        // perf gate from one-off scheduling noise on shared runners.
        let run = |mode: ExecMode| -> (f64, Vec<f32>, u64) {
            let (w1, losses, bytes) = run_once(mode);
            let (w2, losses2, bytes2) = run_once(mode);
            assert_eq!(losses, losses2, "{mode:?} must be run-to-run deterministic");
            assert_eq!(bytes, bytes2);
            (w1.min(w2), losses, bytes)
        };
        let (seq_s, seq_losses, seq_bytes) = run(ExecMode::Sequential);
        let (thr_s, thr_losses, thr_bytes) = run(ExecMode::Threaded);
        if seq_losses != thr_losses || seq_bytes != thr_bytes {
            eprintln!(
                "NUMERICS DIVERGED at {workers} workers: losses {seq_losses:?} vs {thr_losses:?}, bytes {seq_bytes} vs {thr_bytes}"
            );
            std::process::exit(1);
        }
        let speedup = seq_s / thr_s.max(1e-12);
        println!(
            "workers={workers}: sequential {seq_s:.3}s, threaded {thr_s:.3}s, speedup {speedup:.2}x ({seq_bytes} bytes moved)"
        );
        entries.push(obj(vec![
            ("workers", num(workers as f64)),
            ("epochs", num(epochs as f64)),
            ("sequential_s", num(seq_s)),
            ("threaded_s", num(thr_s)),
            ("speedup", num(speedup)),
            ("bytes_moved", num(seq_bytes as f64)),
        ]));
        if workers == 4 {
            seq4 = seq_s;
            thr4 = thr_s;
            speedup4 = speedup;
        }
    }

    let mut doc = BenchDoc::new("pr2_exec_speedup", "BENCH_PR2.json");
    doc.field("graph_n", num(ds.graph.n() as f64));
    doc.field("graph_m", num(ds.graph.m() as f64));
    doc.field("results", arr(entries));
    doc.field("speedup_at_4_workers", num(speedup4));
    doc.gate(
        "threaded_not_slower",
        thr4 <= seq4 * 1.10,
        &format!(
            "PERF GATE FAILED: threaded {thr4:.3}s is >10% slower than sequential {seq4:.3}s at 4 workers"
        ),
    );
    if speedup4 < 1.5 {
        eprintln!(
            "note: speedup {speedup4:.2}x is below the 1.5x target — host may be core-starved"
        );
    }
    doc.finish();
}

//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The registry is unavailable in the hermetic build environment, so —
//! like the hand-rolled JSON layer (serde), CLI parser (clap) and bench
//! harness (criterion) in the main crate — the repo vendors the small
//! slice of `anyhow` it actually uses: a message-carrying [`Error`], the
//! [`Result`] alias, the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the
//! [`Context`] extension trait. Semantics match upstream for this subset;
//! error sources/backtraces are flattened into the message.

use std::fmt;

/// A string-backed error. Like upstream `anyhow::Error`, it deliberately
/// does **not** implement `std::error::Error`, which is what makes the
/// blanket `From<E: Error>` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with higher-level context, outermost first (upstream layout).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulted to [`Error`], as upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::core::format_args!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::core::format_args!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Attach context to the error arm of a `Result` or to a `None`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Debug> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e:?}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e:?}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("got {x} and {}", 8);
        assert_eq!(b.to_string(), "got 7 and 8");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn io_fail() -> Result<()> {
            Err(std::io::Error::other("boom"))?;
            Ok(())
        }
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("boom"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "must be ok");
            Ok(1)
        }
        fn g() -> Result<u32> {
            bail!("always {}", "fails");
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "must be ok");
        assert_eq!(g().unwrap_err().to_string(), "always fails");
    }

    #[test]
    fn error_is_send_sync() {
        fn takes<T: Send + Sync + 'static>(_: T) {}
        takes(anyhow!("threads carry these"));
    }
}
